"""End-to-end training driver with the Pliant runtime as a first-class
feature.

Runs REAL training (CPU-sized configs here; the same code path drives the
production mesh on TPU): data pipeline -> per-variant AOT-compiled train
steps -> Pliant monitor/controller switching variants at step boundaries ->
async checkpointing with elastic restore.

  PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b-smoke \
      --steps 200 --batch 8 --seq 128 [--pliant] [--contention trace.json]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.knobs import ApproxKnobs, PRECISE
from repro.configs import get_config
from repro.core.colocation import SERVICES
from repro.core.explorer import explore
from repro.core.monitor import LatencyMonitor
from repro.core.runtime import PliantRuntime
from repro.core.tenant import TrainTenant
from repro.core.variants import VariantTable
from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.models import api
from repro.train import optim, step as step_mod


def build_variant_steps(cfg, table: VariantTable, opt_cfg, remat="none",
                        mesh=None):
    def factory(knobs: ApproxKnobs):
        fn = step_mod.make_train_step(cfg, knobs, opt_cfg=opt_cfg,
                                      remat=remat, mesh=mesh)
        return jax.jit(fn, donate_argnums=(0, 1))
    table.compile_all(factory)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="phi4-mini-3.8b-smoke")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--pliant", action="store_true",
                   help="enable the Pliant runtime with a synthetic "
                        "contention trace on the token-serve service")
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-period", type=int, default=50)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--decision-interval", type=float, default=0.5)
    p.add_argument("--pod-mesh", action="store_true",
                   help="lay local devices out as a (pod, data) mesh so the "
                        "sync_period/grad_compress knobs exercise the real "
                        "cross-pod collectives (needs >=2 devices, e.g. "
                        "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    p.add_argument("--chaos", default="",
                   help="capacity-event script for the fault injector, e.g. "
                        "'revoke@40:2,restore@120' — revocations live-shrink "
                        "the train mesh (mid-flight optimizer-state reshard "
                        "+ variant recompile), restores grow it back")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = api.init(cfg, key, jnp.float32)
    opt = optim.init_opt(params)
    opt_cfg = optim.OptConfig(lr=args.lr, warmup=20, total_steps=args.steps)

    mesh = None
    if args.pod_mesh:
        if jax.device_count() >= 2:
            from repro.launch.mesh import make_mesh
            n = jax.device_count()
            mesh = make_mesh((2, n // 2), ("pod", "data"))
        else:
            print("WARNING: --pod-mesh ignored (1 device; set XLA_FLAGS="
                  "--xla_force_host_platform_device_count=N) — pod "
                  "collectives will be no-ops")

    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    table = explore(cfg, shape, serving=False, max_variants=4)
    build_variant_steps(cfg, table, opt_cfg, mesh=mesh)

    monitor = LatencyMonitor(SERVICES["token-serve"].qos_target_s)
    # the train job as a first-class Tenant (no elastic reshard actuator on
    # a single host, so its quanta budget is 0 — variant knob only); the
    # same tenant drops into launch/colocate.py's multi-tenant arbiter
    tenant = TrainTenant(table, name="train")
    runtime = PliantRuntime(monitor=monitor, tenants=[tenant])
    runtime.cfg.decision_interval_s = args.decision_interval

    # --chaos: TrainTenant live shrink — the checkpoint-time elastic reshard
    # (save unsharded-logical, re-device_put on any mesh) applied MID-FLIGHT
    # to (params, optimizer state), without the disk round-trip, plus a
    # variant-table recompile on the surviving mesh
    chaos = None
    live = {"params": None, "opt": None, "mesh": mesh, "lost": set()}
    if args.chaos:
        from repro.dist import elastic
        chaos = elastic.FaultInjector.parse(args.chaos)
        base_mesh = mesh

        def on_capacity(ev):
            if ev.kind == elastic.REVOKE:
                if base_mesh is None:
                    print("chaos: revoke ignored (single device, no mesh)")
                    return
                ids = ev.devices or elastic.pick_revoked(
                    base_mesh, ev.count, already=tuple(live["lost"]))
                live["lost"].update(ids)
            elif ev.kind == elastic.RESTORE:
                if ev.devices:
                    live["lost"].difference_update(ev.devices)
                else:
                    live["lost"].clear()
            else:
                return      # quota/collective events: pressure-only here
            if live["lost"]:
                new_mesh, why = elastic.surviving_mesh(base_mesh,
                                                       live["lost"])
                if new_mesh is None:
                    print(f"chaos: cannot shrink ({why}) — degrading via "
                          "the variant ladder only")
                    return
            else:
                new_mesh, why = base_mesh, "full mesh restored"
            t = time.time()
            live["params"], live["opt"] = elastic.reshard_live(
                (live["params"], live["opt"]))
            build_variant_steps(cfg, table, opt_cfg, mesh=new_mesh)
            live["mesh"] = new_mesh
            shape_s = "1x1" if new_mesh is None else \
                "x".join(str(v) for v in new_mesh.shape.values())
            print(f"chaos: resharded (params+opt) onto {shape_s} in "
                  f"{time.time() - t:.2f}s ({why}; lost={sorted(live['lost'])})")

        tenant.elastic_fn = on_capacity
        print(f"chaos: {chaos.pending()} scripted capacity events "
              f"({args.chaos})")

    data_cfg = DataConfig(cfg.vocab_size, args.seq, args.batch,
                          seed=args.seed)
    source = SyntheticLM(data_cfg)
    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, period=args.ckpt_period)
        if args.resume:
            restored, rstep = mgr.restore_latest((params, opt))
            if restored is not None:
                params, opt = restored
                start_step = rstep
                print(f"resumed from step {rstep}")
    prefetch = Prefetcher(lambda s: source.batch(s), start_step)

    losses = []
    svc = SERVICES["token-serve"]
    t0 = time.time()
    for i in range(start_step, args.steps):
        if chaos is not None:
            due = chaos.due(i)
            if due:
                live["params"], live["opt"] = params, opt
                for ev in due:
                    print(f"chaos@{i}: {ev.kind} count={ev.count} "
                          f"quanta={ev.quanta}")
                    runtime.inject(ev)
                params, opt = live["params"], live["opt"]
        step_idx, tokens = next(prefetch)
        batch = {"tokens": jnp.asarray(tokens)}
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                jax.random.fold_in(key, i),
                (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["prefix_embeds"] = jax.random.normal(
                jax.random.fold_in(key, i),
                (args.batch, cfg.n_prefix_tokens, cfg.d_model), jnp.float32)
        step_fn = runtime.step_executable() if args.pliant \
            else table.executable(0)
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
        active_knobs = table.variants[runtime.active_variant].knobs \
            if args.pliant else PRECISE
        if active_knobs.sync_period > 1 \
                and (i + 1) % active_knobs.sync_period == 0:
            # sync-elision knob: the step carries no cross-pod collectives;
            # the driver syncs params every k steps (no-op without a pod axis)
            params = step_mod.pod_sync(params, live["mesh"])
        if args.pliant:
            # synthetic contention trace: mid-run interference burst on the
            # colocated interactive service
            phase = (i - start_step) / max(args.steps - start_step, 1)
            burst = 1.0 if 0.3 < phase < 0.7 else 0.0
            v = table.variants[runtime.active_variant]
            interf = burst * (svc.sens_mem * v.pressure.hbm
                              + svc.sens_ici * v.pressure.ici)
            p99 = svc.p99(0.775, interf, runtime.reclaimed)
            rng = np.random.default_rng(i)
            for x in p99 / 3.2 * np.exp(0.45 * rng.standard_normal(64)):
                monitor.record(float(x))
            runtime.maybe_decide()
        if mgr is not None:
            mgr.maybe_save((params, opt), i + 1)
        if (i + 1) % 20 == 0:
            v = table.variants[runtime.active_variant].name if args.pliant \
                else "precise"
            print(f"step {i+1:5d} loss {np.mean(losses[-20:]):.4f} "
                  f"variant={v} reclaimed={runtime.reclaimed} "
                  f"({(time.time()-t0)/ (i+1-start_step):.2f}s/step)")
    prefetch.close()
    if mgr is not None:
        mgr.save_sync((params, opt), args.steps)
        mgr.wait()
    print(f"final loss {np.mean(losses[-10:]):.4f} "
          f"(first-10 {np.mean(losses[:10]):.4f})")
    if args.pliant:
        switches = [h for h in runtime.history if h["action"] != "hold"]
        print(f"pliant actions: {len(switches)} "
              f"{[h['action'] for h in switches[:8]]}")
    return np.mean(losses[-10:])


if __name__ == "__main__":
    main()
