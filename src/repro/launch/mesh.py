"""Production mesh factory. A FUNCTION (not a module constant) so importing
this module never touches jax device state."""
from __future__ import annotations

import jax

import repro.dist  # noqa: F401  - installs jax.set_mesh/jax.shard_map shims


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — the "
            "dry-run entrypoint must set XLA_FLAGS="
            "--xla_force_host_platform_device_count=512 before importing jax")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_mesh(shape, axes):
    """Arbitrary mesh over the first prod(shape) devices (tests, elastic)."""
    import numpy as np
    n = int(np.prod(shape))
    return jax.sharding.Mesh(np.asarray(jax.devices()[:n]).reshape(shape),
                             axes)
