"""Serving driver: continuous-batching engine over a reduced config, with
Pliant serving knobs selectable per run (precise / int8 / int8+kv-quant).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-27b-smoke \
      --requests 16 --slots 4 --max-new 12 [--variant int8_kvq]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.knobs import ApproxKnobs
from repro.configs import get_config
from repro.models import api
from repro.serve.engine import Request, ServeEngine

VARIANTS = {
    "precise": ApproxKnobs(),
    "int8": ApproxKnobs(matmul_precision="int8"),
    "kvq": ApproxKnobs(kv_quant=True),
    "int8_kvq": ApproxKnobs(matmul_precision="int8", kv_quant=True),
}


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="gemma2-27b-smoke")
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-new", type=int, default=12)
    p.add_argument("--max-len", type=int, default=64)
    p.add_argument("--variant", default="precise", choices=list(VARIANTS))
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    params = api.init(cfg, jax.random.PRNGKey(args.seed), jnp.float32)
    eng = ServeEngine(cfg, batch_slots=args.slots, max_len=args.max_len,
                      params=params, knobs=VARIANTS[args.variant])
    rng = np.random.default_rng(args.seed)
    reqs = [Request(i, prompt=list(rng.integers(1, cfg.vocab_size, 4)),
                    max_new=args.max_new) for i in range(args.requests)]
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    eng.run()
    wall = time.perf_counter() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    print(f"{args.variant}: {done}/{len(reqs)} requests, {toks} tokens in "
          f"{wall:.2f}s ({1e3*np.mean(eng.step_latencies):.1f} ms/step, "
          f"{toks/wall:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    main()
