"""Open-loop serving driver: Poisson arrivals into the continuous-batching
engine, with the Pliant control loop (monitor -> controller -> variant
hot-swap) closed over per-token latency.

Serving variants come from the explorer's serving-applicable grid — one
source of truth with the colocation benchmarks, ordered precise-first.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-27b-smoke \
      --requests 16 --slots 4 --max-new 12 --rate 50 --qos-target 0.05

``--qos-target 0`` disables control (pin a variant with ``--variant``);
``--mesh 2x4`` serves sharded over an 8-device (data, model) mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.controller import ControllerConfig
from repro.core.explorer import explore
from repro.core.monitor import LatencyMonitor
from repro.core.runtime import PliantRuntime
from repro.core.variants import VariantTable
from repro.models import api
from repro.serve.engine import Request, ServeEngine


def serving_table(cfg: ModelConfig, *, slots: int, max_len: int,
                  max_loss: float = 0.05,
                  page_occupancy: float = None,
                  price_from_compile: bool = False) -> VariantTable:
    """The serving VariantTable for one engine shape, from the explorer.

    ``page_occupancy``: expected live-page fraction of a paged engine —
    prices decode HBM by live pages so the frontier sees paged savings.
    ``price_from_compile`` anchors that pricing on the compiled decode
    cell's ``cost_analysis`` bytes (``explorer.decode_kv_share``) instead
    of the coarse heuristic — one extra compile, so opt-in."""
    shape = ShapeConfig("serve", max_len, slots, "decode")
    kv_share = None
    if price_from_compile and page_occupancy is not None:
        from repro.core.explorer import decode_kv_share
        kv_share = decode_kv_share(cfg, slots, max_len)
    return explore(cfg, shape, serving=True, max_loss=max_loss,
                   page_occupancy=page_occupancy, kv_share=kv_share)


def percentiles(lat, ps=(50, 95, 99)):
    if not lat:
        return {p: float("nan") for p in ps}
    a = np.asarray(lat, float)
    return {p: float(np.percentile(a, p)) for p in ps}


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="gemma2-27b-smoke")
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-new", type=int, default=12)
    p.add_argument("--max-len", type=int, default=64)
    p.add_argument("--prompt-len", type=int, default=6)
    p.add_argument("--prefill-chunk", type=int, default=16)
    p.add_argument("--rate", type=float, default=0.0,
                   help="Poisson arrival rate (req/s); 0 = all at t=0")
    p.add_argument("--qos-target", type=float, default=0.0,
                   help="per-token latency QoS target (s); 0 = no control")
    p.add_argument("--decision-interval", type=float, default=0.25)
    p.add_argument("--variant", default=None,
                   help="pin a variant by name (e.g. int8); default precise "
                        "or Pliant-controlled when --qos-target is set")
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--mesh", default="",
                   help="serve sharded, e.g. 2x4 -> (data=2, model=4)")
    p.add_argument("--paged", action="store_true",
                   help="paged page-pool caches with prefix reuse and the "
                        "pool_pages Pliant knob (default: dense rings)")
    p.add_argument("--page-size", type=int, default=8)
    p.add_argument("--pool-pages", type=int, default=0,
                   help="physical pages (0 = auto-size)")
    p.add_argument("--shared-prefix", type=int, default=0,
                   help="first N prompt tokens identical across requests "
                        "(exercises the prefix cache under --paged)")
    p.add_argument("--megastep", type=int, default=0,
                   help="fuse up to K decode steps per dispatch (lax.scan "
                        "megastep: on-device sampling + EOS/budget stop "
                        "masking, async double-buffered host loop); paged "
                        "only, 0 = one dispatch per token")
    p.add_argument("--eos-id", type=int, default=-1,
                   help="stop-token id; a request emitting it finishes "
                        "early (-1 = generate max-new tokens)")
    p.add_argument("--sync-timing", action="store_true",
                   help="drain every megastep before dispatching the next: "
                        "no pipeline overlap, but per-token stamps measure "
                        "compute instead of dispatch enqueue (benchmarks)")
    p.add_argument("--no-donate", action="store_true",
                   help="keep cache buffers undonated (XLA double-buffers "
                        "the pool; for debugging stale-reference holds)")
    p.add_argument("--max-admission-chunks", type=int, default=4,
                   help="prefill-chunk burst per step when no decoder is "
                        "inside its QoS guard band (continuous batching)")
    p.add_argument("--qos-guard", type=float, default=0.25,
                   help="guard band: burst admission chunks only while "
                        "monitor p99 <= (1 - guard) * QoS target")
    p.add_argument("--chaos", default="",
                   help="capacity-event script for the fault injector, "
                        "e.g. 'revoke@20+4:2,restore@60' (dist.elastic "
                        "grammar: kind@step[+grace][:count])")
    p.add_argument("--admission-timeout", type=float, default=0.0,
                   help="reject a queued request after waiting this many "
                        "seconds without admission (0 = wait forever)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    params = api.init(cfg, jax.random.PRNGKey(args.seed), jnp.float32)
    occupancy = (min(1.0, (args.prompt_len + args.max_new) / args.max_len)
                 if args.paged else None)
    table = serving_table(cfg, slots=args.slots, max_len=args.max_len,
                          page_occupancy=occupancy,
                          price_from_compile=args.paged)
    names = [v.name for v in table.variants]

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_mesh
        shape = tuple(int(x) for x in args.mesh.split("x"))
        assert len(shape) == 2, "--mesh must be DxM (data x model)"
        mesh = make_mesh(shape, ("data", "model"))

    runtime = None
    if args.qos_target > 0:
        # tail-estimate floor scaled to engine width: one step contributes at
        # most `slots` samples, and slow (compile-heavy) steps mean a decision
        # window may span a single step — don't let the estimator starve
        monitor = LatencyMonitor(qos_target_s=args.qos_target, window=1024,
                                 min_samples=min(20, max(4, 2 * args.slots)))
        runtime = PliantRuntime(table, monitor, ControllerConfig(
            decision_interval_s=args.decision_interval))
    eng = ServeEngine(cfg, batch_slots=args.slots, max_len=args.max_len,
                      params=params, table=table, runtime=runtime,
                      temperature=args.temperature, mesh=mesh,
                      prefill_chunk=args.prefill_chunk, seed=args.seed,
                      paged=args.paged, page_size=args.page_size,
                      n_pages=args.pool_pages,
                      max_admission_chunks=args.max_admission_chunks,
                      qos_guard=args.qos_guard,
                      admission_timeout_s=args.admission_timeout,
                      megastep_k=args.megastep, eos_id=args.eos_id,
                      sync_timing=args.sync_timing,
                      donate=not args.no_donate)
    print(f"dispatch: {eng.explain_dispatch()}")
    print(f"dispatch: {eng.explain_prefill_dispatch()}")
    print(f"dispatch: {eng.explain_megastep()}")
    injector = None
    if args.chaos:
        from repro.dist import elastic
        injector = elastic.FaultInjector.parse(args.chaos)
        print(f"chaos: {injector.pending()} scripted capacity events "
              f"({args.chaos})")
    if args.variant is not None:
        eng.set_variant(names.index(args.variant))

    rng = np.random.default_rng(args.seed)
    shared = list(rng.integers(1, cfg.vocab_size,
                               min(args.shared_prefix, args.prompt_len)))
    reqs = [Request(i, prompt=shared + list(rng.integers(
                        1, cfg.vocab_size, args.prompt_len - len(shared))),
                    max_new=args.max_new) for i in range(args.requests)]
    arrivals = (np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
                if args.rate > 0 else np.zeros(args.requests))

    t0 = time.perf_counter()
    nxt, steps = 0, 0
    while not all(r.done or r.rejected for r in reqs) and steps < 100_000:
        now = time.perf_counter() - t0
        while nxt < len(reqs) and arrivals[nxt] <= now:
            reqs[nxt].t_arrival = t0 + arrivals[nxt]
            eng.submit(reqs[nxt])
            nxt += 1
        if injector is not None:
            for ev in injector.due(steps):
                print(f"chaos@{steps}: {ev.kind} count={ev.count} "
                      f"quanta={ev.quanta} grace={ev.deadline_steps}")
                eng.inject(ev)
        if eng.idle:                 # queue, in-flight admission, slots all empty
            if nxt < len(reqs):      # open loop: idle until the next arrival
                time.sleep(min(arrivals[nxt] - now, 0.01))
                continue
            break
        eng.step()
        steps += 1
    wall = time.perf_counter() - t0

    # per-token latency seen by each request (inter-token gap; first token's
    # gap runs from arrival, so it includes queueing + admission prefill)
    tok_lat, ttft, queue_wait, admit_compute = [], [], [], []
    for r in reqs:
        if not r.token_times:
            continue
        ts = [r.t_arrival or r.t_admit] + r.token_times
        tok_lat.extend(b - a for a, b in zip(ts, ts[1:]))
        ttft.append(r.token_times[0] - ts[0])
        if r.t_arrival and r.t_admit_start:
            # now that prefill interleaves with decode (paged stall-free
            # loop), the old arrival->completion delta mixed three things;
            # report queue WAIT (arrival -> first chunk issued) separately
            # from admission COMPUTE (pure prefill executable time)
            queue_wait.append(r.t_admit_start - r.t_arrival)
        if r.t_admit:
            admit_compute.append(r.admit_compute_s)
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    pct = percentiles(tok_lat)
    viol = (float(np.mean(np.asarray(tok_lat) > args.qos_target))
            if args.qos_target > 0 and tok_lat else 0.0)
    print(f"variants: {names} (active={names[eng.active_variant]})")
    print(f"{done}/{len(reqs)} requests, {toks} tokens in {wall:.2f}s "
          f"({toks / max(wall, 1e-9):.1f} tok/s, rate={args.rate}/s)")
    ttft95 = float(np.percentile(ttft, 95)) if ttft else float("nan")
    q95 = float(np.percentile(queue_wait, 95)) if queue_wait else 0.0
    a95 = float(np.percentile(admit_compute, 95)) if admit_compute else 0.0
    print(f"per-token latency ms: p50={1e3 * pct[50]:.1f} "
          f"p95={1e3 * pct[95]:.1f} p99={1e3 * pct[99]:.1f}  "
          f"ttft p95={1e3 * ttft95:.1f}  queue-wait p95={1e3 * q95:.1f}  "
          f"admit-compute p95={1e3 * a95:.1f}")
    if args.paged:
        s = eng.pool.stats
        looks = s["prefix_hits"] + s["prefix_misses"]
        chunks = [c for c, _ in eng.step_admission_chunks]
        print(f"paged: pages={eng.pool.spec.n_pages} "
              f"occupancy={eng.pool.occupancy():.2f} "
              f"peak_used={s['peak_used']} "
              f"prefix_hit_rate={s['prefix_hits'] / max(looks, 1):.2f} "
              f"tokens_skipped={s['tokens_skipped']} "
              f"reclaim_events={s['reclaim_events']}")
        print(f"admission: grouped_pages={s['grouped_pages']} "
              f"grouped_fallbacks={s['grouped_fallbacks']} "
              f"replenish_evictions={s['replenish_evictions']} "
              f"chunks/step max={max(chunks, default=0)} "
              f"budget_cap={args.max_admission_chunks}")
    if args.megastep:
        d_t = eng.row_dispatches / max(eng.row_tokens, 1)
        print(f"megastep: k={args.megastep} "
              f"decode_dispatches={eng.decode_dispatches} "
              f"dispatches/token={d_t:.2f} "
              f"drain_block_s={eng.drain_block_s:.3f}")
    if args.qos_target > 0:
        acts = [h["action"] for h in runtime.history if h["action"] != "hold"]
        print(f"qos: target={1e3 * args.qos_target:.1f}ms "
              f"violation_rate={viol:.3f} swaps={eng.swaps} actions={acts}")
    if args.chaos or args.admission_timeout > 0:
        s = eng.stats
        rehomes = [e for e in eng.elastic_log if "mesh_shape" in e]
        print(f"elastic: events={s['capacity_events']} "
              f"rehomes={s['rehomes']} "
              f"collective_retries={s['collective_retries']} "
              f"recovery_steps={[e['recovery_steps'] for e in rehomes]} "
              f"rejected={len(eng.rejected)} "
              f"timeouts={s['admission_timeouts']} "
              f"backoff_skips={s['backoff_skips']}")
        for r in eng.rejected:
            rej = r.rejection
            print(f"  rejected uid={rej.uid} waited={rej.waited_s:.3f}s "
                  f"queue_depth={rej.queue_depth} step={rej.step}")
    return 0


if __name__ == "__main__":
    main()
