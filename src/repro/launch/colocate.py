"""Colocation harness: a REAL paged serving engine and a REAL train-step
job co-run on shared devices under ONE multi-tenant arbiter.

This is the multi-tenant control plane on the real code paths — not the
queueing model: the interactive tenant is a paged ``ServeEngine`` whose
per-token latencies feed the ``LatencyMonitor``; the batch tenant runs its
variant's AOT-compiled train step between engine steps. Both are ``Tenant``
adapters under one ``InterferenceAwareArbiter`` (or the round-robin
baseline with ``--arbiter round_robin``):

* serve tenant — variant hot-swap (``request_variant``, deferred mid-
  admission) + ``pool_pages`` quanta (prefix cache evicted first);
* train tenant — variant hot-swap (executable table) + a DUTY-CYCLE quanta
  actuator: reclaiming k of its ``--train-groups`` quanta skips k of every
  ``--train-groups`` loop turns, genuinely yielding the shared substrate's
  step-loop share to the serving engine (the single-host analogue of the
  elastic chip-group reshard).

  PYTHONPATH=src python -m repro.launch.colocate \
      --serve-arch gemma2-27b-smoke --train-arch phi4-mini-3.8b-smoke \
      --requests 8 --slots 2 --max-new 6 --qos-target 0.05
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.arbiter import InterferenceAwareArbiter, RoundRobinArbiter
from repro.core.colocation import SERVICES
from repro.core.controller import ControllerConfig
from repro.core.explorer import explore
from repro.core.monitor import LatencyMonitor
from repro.core.runtime import PliantRuntime
from repro.core.tenant import ServeTenant, TrainTenant
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.serve import serving_table
from repro.launch.train import build_variant_steps
from repro.models import api
from repro.serve.engine import Request, ServeEngine
from repro.train import optim


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--serve-arch", default="gemma2-27b-smoke")
    p.add_argument("--train-arch", default="phi4-mini-3.8b-smoke")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=2)
    p.add_argument("--max-new", type=int, default=6)
    p.add_argument("--max-len", type=int, default=48)
    p.add_argument("--prompt-len", type=int, default=6)
    p.add_argument("--page-size", type=int, default=4)
    p.add_argument("--rate", type=float, default=100.0,
                   help="Poisson arrival rate (req/s); 0 = all at t=0")
    p.add_argument("--qos-target", type=float, default=0.05,
                   help="per-token latency QoS target (s)")
    p.add_argument("--decision-interval", type=float, default=0.05)
    p.add_argument("--train-batch", type=int, default=4)
    p.add_argument("--train-seq", type=int, default=64)
    p.add_argument("--train-groups", type=int, default=8,
                   help="duty-cycle quanta of the train tenant (reclaiming "
                        "k skips k of every train-groups loop turns)")
    p.add_argument("--arbiter", default="interference",
                   choices=["interference", "round_robin"])
    p.add_argument("--service", default="token-serve", choices=list(SERVICES),
                   help="sensitivity vector for contention attribution")
    p.add_argument("--json", default="", help="write summary JSON here")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    # ----------------------------------------------------- serve tenant ----
    scfg = get_config(args.serve_arch)
    sparams = api.init(scfg, jax.random.PRNGKey(args.seed), jnp.float32)
    stable = serving_table(
        scfg, slots=args.slots, max_len=args.max_len,
        page_occupancy=min(1.0, (args.prompt_len + args.max_new)
                           / args.max_len))
    eng = ServeEngine(scfg, batch_slots=args.slots, max_len=args.max_len,
                      params=sparams, table=stable, paged=True,
                      page_size=args.page_size, seed=args.seed)
    serve_tenant = ServeTenant(engine=eng, name="serve")

    # ----------------------------------------------------- train tenant ----
    tcfg = get_config(args.train_arch)
    assert tcfg.family not in ("encdec", "vlm"), \
        "colocate's synthetic batch covers token-only families"
    tparams = api.init(tcfg, jax.random.PRNGKey(args.seed + 1), jnp.float32)
    topt = optim.init_opt(tparams)
    opt_cfg = optim.OptConfig(lr=1e-3, warmup=5, total_steps=1000)
    shape = ShapeConfig("cli", args.train_seq, args.train_batch, "train")
    ttable = explore(tcfg, shape, serving=False, max_variants=3)
    build_variant_steps(tcfg, ttable, opt_cfg)
    yielded = {"k": 0}      # duty-cycle actuator state (absolute quanta out)
    train_tenant = TrainTenant(
        ttable, name="train", reshard_fn=lambda k: yielded.update(k=k),
        max_reclaim=args.train_groups - 1, n_quanta=args.train_groups)

    # ------------------------------------------- one arbiter, two tenants --
    tenants = [serve_tenant, train_tenant]
    cfg = ControllerConfig(decision_interval_s=args.decision_interval)
    svc = SERVICES[args.service]
    if args.arbiter == "interference":
        arb = InterferenceAwareArbiter.from_tenants(
            tenants, cfg, sensitivity=svc.sensitivity)
    else:
        arb = RoundRobinArbiter.from_tenants(tenants, cfg)
    # tail-estimate floor scaled to engine width: one decode step contributes
    # at most ``slots`` samples and every decision consumes the window, so a
    # higher floor would starve the controller of any signal
    monitor = LatencyMonitor(qos_target_s=args.qos_target, window=1024,
                             min_samples=max(2, args.slots))
    runtime = PliantRuntime(monitor=monitor, cfg=cfg, tenants=tenants,
                            arbiter=arb)
    # the engine drives the shared control loop at its step boundaries
    # (latency feed + decision ticks); actuation arrives back through the
    # tenant adapters — including for the train job
    eng.attach_runtime(runtime, serve_tenant)

    # ------------------------------------------------------- open loop -----
    rng = np.random.default_rng(args.seed)
    reqs = [Request(i, prompt=list(rng.integers(1, scfg.vocab_size,
                                                args.prompt_len)),
                    max_new=args.max_new) for i in range(args.requests)]
    arrivals = (np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
                if args.rate > 0 else np.zeros(args.requests))
    data = SyntheticLM(DataConfig(tcfg.vocab_size, args.train_seq,
                                  args.train_batch, seed=args.seed))

    t0 = time.perf_counter()
    nxt = it = train_steps = train_skipped = 0
    train_qloss = 0.0
    losses = []
    while not all(r.done for r in reqs):
        now = time.perf_counter() - t0
        while nxt < len(reqs) and arrivals[nxt] <= now:
            reqs[nxt].t_arrival = t0 + arrivals[nxt]
            eng.submit(reqs[nxt])
            nxt += 1
        if not eng.idle:
            eng.step()
        # train tenant's duty cycle: run the step unless this turn is one of
        # the `yielded` skipped turns per `train-groups` window
        if it % args.train_groups >= yielded["k"]:
            step_fn = ttable.executable(train_tenant.variant)
            batch = {"tokens": jnp.asarray(data.batch(train_steps))}
            tparams, topt, metrics = step_fn(tparams, topt, batch)
            losses.append(float(metrics["loss"]))
            train_qloss += ttable.variants[train_tenant.variant].quality_loss
            train_steps += 1
        else:
            train_skipped += 1
        it += 1
        if eng.idle and nxt < len(reqs):
            time.sleep(max(0.0, min(arrivals[nxt]
                                    - (time.perf_counter() - t0), 0.005)))
    wall = time.perf_counter() - t0

    # --------------------------------------------------------- summary -----
    tok_lat = []
    for r in reqs:
        ts = [r.t_arrival or r.t_admit] + r.token_times
        tok_lat.extend(b - a for a, b in zip(ts, ts[1:]))
    toks = sum(len(r.out) for r in reqs)
    acts = [h for h in runtime.history if h["action"] != "hold"]
    victims = {t.name: sum(1 for h in acts if h["victim"] == i)
               for i, t in enumerate(tenants)}
    summary = {
        "arbiter": args.arbiter,
        "requests_done": int(sum(r.done for r in reqs)),
        "tokens": int(toks),
        "wall_s": wall,
        "tok_per_s": toks / max(wall, 1e-9),
        "p99_token_ms": (1e3 * float(np.percentile(tok_lat, 99))
                         if tok_lat else float("nan")),
        "violation_rate": (float(np.mean(np.asarray(tok_lat)
                                         > args.qos_target))
                           if tok_lat else 0.0),
        "train_steps": train_steps,
        "train_skipped": train_skipped,
        "train_mean_quality_loss": train_qloss / max(train_steps, 1),
        "train_final_loss": float(np.mean(losses[-5:])) if losses else None,
        "serve_variant": eng.active_variant,
        "train_variant": train_tenant.variant,
        "serve_reclaimed_pages": eng.pool.reclaimed,
        "train_yielded_quanta": yielded["k"],
        "actions": len(acts),
        "victims": victims,
        "swaps": eng.swaps,
    }
    print(f"[{args.arbiter}] {summary['requests_done']}/{len(reqs)} requests,"
          f" {toks} tokens in {wall:.2f}s ({summary['tok_per_s']:.1f} tok/s)")
    print(f"p99 token {summary['p99_token_ms']:.1f}ms "
          f"(target {1e3 * args.qos_target:.1f}ms, "
          f"violation_rate={summary['violation_rate']:.3f})")
    print(f"train: {train_steps} steps ({train_skipped} yielded turns), "
          f"variant={train_tenant.variant}, "
          f"mean_qloss={summary['train_mean_quality_loss']:.4f}")
    print(f"arbiter: {len(acts)} actions, victims={victims}, "
          f"serve_variant={eng.active_variant} "
          f"pool_reclaimed={eng.pool.reclaimed} "
          f"train_yielded={yielded['k']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=1)
    return summary


if __name__ == "__main__":
    main()
