import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct stand-ins (no allocation), prove it fits via
``memory_analysis()``, and extract roofline inputs (``cost_analysis()`` +
collective bytes parsed from optimized HLO) into a JSON artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-780m \
      --shape train_4k --mesh pod [--variant int8] [--n-micro 4] \
      [--remat full] [--policy fsdp_tp] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro import flags
from repro import roofline
from repro.approx.knobs import ApproxKnobs, PRECISE
from repro.configs import SHAPES, get_config, shape_applicable
from repro.dist import sharding
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.train import optim, step as step_mod

VARIANTS = {
    "precise": PRECISE,
    "int8": ApproxKnobs(matmul_precision="int8"),
    "drop25": ApproxKnobs(token_drop=0.25),
    "skip25": ApproxKnobs(layer_skip=0.25),
    "kvstride2": ApproxKnobs(kv_keep_stride=2),
    "topk_half": None,     # resolved per-arch below
    "int8_kvq": ApproxKnobs(matmul_precision="int8", kv_quant=True),
    "gint8": ApproxKnobs(grad_compress="int8"),   # int8-wire pod grad reduce
}


def resolve_variant(name: str, cfg) -> ApproxKnobs:
    if name == "topk_half":
        if cfg.moe is None:
            raise SystemExit(f"{cfg.name} has no MoE top-k knob")
        return ApproxKnobs(topk_override=max(1, cfg.moe.top_k // 2))
    return VARIANTS[name]


def lower_cell(cfg, shape, mesh, knobs, *, policy=None, n_micro=1,
               remat="full"):
    """Returns (lowered, n_chips). Abstract everything: no device arrays."""
    from repro.dist import annotate
    b_spec = sharding.batch_pspec(shape.global_batch, mesh)
    pol = policy or sharding.default_policy(cfg)
    annotate.set_batch_axes(b_spec[0] if len(b_spec) else None,
                            fsdp_axis="data" if pol == "fsdp_tp" else None)
    params_sh = sharding.param_shardings(cfg, mesh, policy)
    abstract_params = api.abstract(cfg)
    in_sh = sharding.input_shardings(cfg, shape, mesh)
    in_specs = api.input_specs(cfg, shape)
    ep_axis = "model" if (cfg.moe is not None and "model" in mesh.shape) \
        else None

    if shape.kind == "train":
        opt_abs = jax.eval_shape(optim.init_opt, abstract_params)
        opt_sh = optim.OptState(
            step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            m=jax.tree.map(lambda s: s, params_sh),
            v=jax.tree.map(lambda s: s, params_sh))
        fn = step_mod.make_train_step(cfg, knobs, n_micro=n_micro,
                                      remat=remat, ep_axis=ep_axis, mesh=mesh,
                                      param_pspecs=params_sh)
        jitted = jax.jit(fn,
                         in_shardings=(params_sh, opt_sh, in_sh),
                         out_shardings=(params_sh, opt_sh, None),
                         donate_argnums=(0, 1))
        with jax.set_mesh(mesh):
            return jitted.lower(abstract_params, opt_abs, in_specs)

    if shape.kind == "prefill":
        fn = step_mod.make_prefill_fn(cfg, knobs, ep_axis=ep_axis, mesh=mesh,
                                      remat=remat)
        jitted = jax.jit(fn, in_shardings=(params_sh, in_sh),
                         out_shardings=None)
        with jax.set_mesh(mesh):
            return jitted.lower(abstract_params, in_specs)

    # decode
    cache_sh, caches_abs = sharding.cache_shardings(cfg, shape, mesh)
    fn = step_mod.make_serve_step(cfg, knobs, ep_axis=ep_axis, mesh=mesh)
    extra, extra_sh = (), ()
    if cfg.family == "encdec":
        enc_spec = in_specs.pop("enc_out")
        enc_sh = in_sh.pop("enc_out")
        extra, extra_sh = (enc_spec,), (enc_sh,)
    arg_sh = (params_sh, in_sh["tokens"], in_sh["position"], cache_sh) \
        + extra_sh
    jitted = jax.jit(fn, in_shardings=arg_sh,
                     out_shardings=(None, cache_sh),
                     donate_argnums=(3,))
    with jax.set_mesh(mesh):
        return jitted.lower(abstract_params, in_specs["tokens"],
                            in_specs["position"], caches_abs, *extra)


def loop_trips(cfg, shape, knobs, n_micro: int, remat: str):
    """Extra-body multipliers per structural loop site (see flags.py).

    Each value is the number of EXTRA copies of that site's loop body present
    in the true program relative to the base compile — nesting-aware: a site
    nested inside loops with total outer trip count T and own trip count n
    contributes T*(n-1) extra bodies, while each enclosing probe's delta
    already carries exactly one copy of the inner body (the algebra closes:
    sum_i mult_i * d_i reconstructs the fully-unrolled cost; validated in
    tests/test_dryrun_accounting.py).
    """
    from repro.approx.knobs import keep_groups
    from repro.models.lm import _near_sqrt_factors
    mult = {}
    g = len(keep_groups(cfg.n_groups, knobs.layer_skip))
    mic = n_micro if shape.kind == "train" else 1
    if mic > 1:
        mult["micro"] = mic - 1
    if remat == "2level" and shape.kind in ("train", "prefill"):
        no, ni = _near_sqrt_factors(g)
        if no > 1:
            mult["groups_outer"] = mic * (no - 1)
            mult["groups"] = mic * no * (ni - 1)
        else:
            mult["groups"] = mic * (g - 1)
    else:
        mult["groups"] = mic * (g - 1)
    if shape.kind == "train":
        from repro.models.lm import ce_chunk
        s_text = shape.seq_len - (cfg.n_prefix_tokens or 0)
        nc_ce = s_text // ce_chunk(s_text)
        if nc_ce > 1:
            mult["ce"] = mic * (nc_ce - 1)
    # (no "ssd" site: the SSD chunk-state recurrence is a static python loop
    # in kernels/ref.py — every chunk body is already in the base compile)
    if cfg.family == "encdec" and shape.kind != "decode":
        if cfg.n_encoder_layers > 1:
            mult["enc"] = mic * (cfg.n_encoder_layers - 1)
    return {k: v for k, v in mult.items() if v > 0}


def _compile_and_measure(cfg, shape, mesh, knobs, *, policy, n_micro, remat):
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh, knobs, policy=policy,
                         n_micro=n_micro, remat=remat)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax<=0.4.x: one dict per program
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    coll = roofline.collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "mem": mem,
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str, variant: str,
             *, policy=None, n_micro=1, remat="full", out_dir="results/dryrun",
             tag="", probe_loops=True, probe3=False):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        print(f"SKIP {arch} x {shape_name}: {reason}")
        return {"skipped": reason, "arch": arch, "shape": shape_name}
    knobs = resolve_variant(variant, cfg)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    if knobs.grad_compress != "none" and "pod" not in mesh.shape:
        # without a pod axis the compressed reduce is a no-op and the cell
        # would silently measure identically to precise under a gint8 label
        reason = "grad_compress needs a pod axis (--mesh multipod)"
        print(f"SKIP {arch} x {shape_name} x {variant}: {reason}")
        return {"skipped": reason, "arch": arch, "shape": shape_name}
    n_chips = mesh.size

    flags.reset_unroll()
    base = _compile_and_measure(cfg, shape, mesh, knobs, policy=policy,
                                n_micro=n_micro, remat=remat)
    mults = loop_trips(cfg, shape, knobs, n_micro, remat) if probe_loops \
        else {}
    flops = base["flops"]
    bytes_acc = base["bytes_accessed"]
    coll = dict(base["collectives"])
    probes = {}
    for site, extra in mults.items():
        flags.reset_unroll()
        flags.set_unroll(site, 2)
        p2 = _compile_and_measure(cfg, shape, mesh, knobs, policy=policy,
                                  n_micro=n_micro, remat=remat)
        if probe3:
            # 3-point probe: f(k) = base + k*b + c, where c is a one-time
            # fusion-break cost at the first unroll. Marginal clean body
            # b = f(3) - f(2); the break cost c is added once.
            flags.reset_unroll()
            flags.set_unroll(site, 3)
            p3 = _compile_and_measure(cfg, shape, mesh, knobs, policy=policy,
                                      n_micro=n_micro, remat=remat)
            d_flops = max(p3["flops"] - p2["flops"], 0.0)
            d_bytes = max(p3["bytes_accessed"] - p2["bytes_accessed"], 0.0)
            c_flops = max(p2["flops"] - base["flops"] - d_flops, 0.0)
            c_bytes = max(p2["bytes_accessed"] - base["bytes_accessed"]
                          - d_bytes, 0.0)
            flops += extra * d_flops + c_flops
            bytes_acc += extra * d_bytes + c_bytes
            coll_ref = p2["collectives"]
            coll_d = {k: max(p3["collectives"].get(k, 0.0)
                             - p2["collectives"].get(k, 0.0), 0.0)
                      for k in set(p3["collectives"]) | set(coll_ref)}
        else:
            d_flops = max(p2["flops"] - base["flops"], 0.0)
            d_bytes = max(p2["bytes_accessed"] - base["bytes_accessed"], 0.0)
            flops += extra * d_flops
            bytes_acc += extra * d_bytes
            coll_d = {k: max(p2["collectives"].get(k, 0.0)
                             - base["collectives"].get(k, 0.0), 0.0)
                      for k in set(p2["collectives"])
                      | set(base["collectives"])}
        for k, d in coll_d.items():
            coll[k] = coll.get(k, 0.0) + extra * d
        probes[site] = {"extra": extra, "d_flops": d_flops,
                        "d_bytes": d_bytes, "compile_s": p2["compile_s"],
                        "probe3": probe3}
    flags.reset_unroll()

    mem = base["mem"]
    art = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "variant": variant,
        "policy": policy or sharding.default_policy(cfg),
        "n_micro": n_micro, "remat": remat, "n_chips": n_chips,
        "flops": flops,
        "bytes_accessed": bytes_acc,
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "peak_bytes_est": int(mem.argument_size_in_bytes
                              + mem.temp_size_in_bytes
                              + mem.output_size_in_bytes
                              - mem.alias_size_in_bytes),
        "collectives": coll,
        "probes": probes,
        "lower_s": base["lower_s"], "compile_s": base["compile_s"],
    }
    mf = roofline.model_flops(cfg, shape, knobs)
    terms = roofline.terms_from_artifact(art, mf, n_chips)
    art.update({
        "model_flops_total": mf,
        "compute_s": terms.compute_s, "memory_s": terms.memory_s,
        "collective_s": terms.collective_s, "dominant": terms.dominant,
        "useful_ratio": terms.useful_ratio,
        "roofline_fraction": terms.roofline_fraction,
    })
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape_name}__{mesh_kind}__{variant}"
    if tag:
        name += f"__{tag}"
    (out / f"{name}.json").write_text(json.dumps(art, indent=1))
    print(f"OK {name}: flops/chip={art['flops']:.3e} "
          f"bytes={art['bytes_accessed']:.3e} "
          f"wire={sum(coll.values()):.3e} peak={art['peak_bytes_est']/2**30:.2f}GiB "
          f"dominant={art['dominant']} frac={art['roofline_fraction']:.3f} "
          f"(lower {art['lower_s']}s compile {art['compile_s']}s)")
    return art


def run_pod_sync(arch: str, *, compress: bool, out_dir="results/dryrun"):
    """Quantify the sync-elision knob: compile the periodic cross-pod param
    sync as its own step and record its wire bytes. A train step under
    ``sync_period=k`` carries NO pod collectives; its amortized collective
    term is train_wire + sync_wire / k (EXPERIMENTS.md §Variants)."""
    from repro.dist.collectives import pod_sync_params
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=True)
    params_abs = api.abstract(cfg)
    params_sh = sharding.param_shardings(cfg, mesh)
    jitted = jax.jit(lambda p: pod_sync_params(p, mesh, compress=compress,
                                               pspecs=params_sh),
                     in_shardings=(params_sh,), out_shardings=params_sh)
    with jax.set_mesh(mesh):
        compiled = jitted.lower(params_abs).compile()
    coll = roofline.collective_bytes(compiled.as_text())
    art = {"arch": arch, "kind": "pod_sync", "compress": compress,
           "collectives": coll, "wire_bytes": sum(coll.values()),
           "collective_s": sum(coll.values()) / roofline.ICI_BW}
    name = f"{arch}__podsync__multipod__{'int8' if compress else 'precise'}"
    pathlib.Path(out_dir).mkdir(parents=True, exist_ok=True)
    (pathlib.Path(out_dir) / f"{name}.json").write_text(
        json.dumps(art, indent=1))
    print(f"OK {name}: wire={art['wire_bytes']:.3e} B "
          f"({art['collective_s']:.3f}s @ICI)")
    return art


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    p.add_argument("--variant", default="precise")
    p.add_argument("--policy", default=None)
    p.add_argument("--n-micro", type=int, default=1)
    p.add_argument("--remat", default="full")
    p.add_argument("--out", default="results/dryrun")
    p.add_argument("--tag", default="")
    p.add_argument("--probe3", action="store_true",
                   help="3-point loop probes (removes one-time fusion-break "
                        "bias; used for hillclimb cells)")
    p.add_argument("--decode2d", action="store_true",
                   help="weight-stationary decode: batch unsharded, weights "
                        "2D-sharded, cache sequence over all axes")
    p.add_argument("--all", action="store_true")
    p.add_argument("--pod-sync", action="store_true",
                   help="measure the cross-pod param-sync step instead")
    p.add_argument("--compress", action="store_true")
    args = p.parse_args()
    if args.pod_sync:
        run_pod_sync(args.arch, compress=args.compress, out_dir=args.out)
        return
    if args.decode2d:
        from jax.sharding import PartitionSpec as _P
        sharding.batch_pspec = lambda *a, **k: _P()

    if args.all:
        from repro.configs import ARCHS
        failures = []
        for arch in ARCHS:
            for shape_name in SHAPES:
                try:
                    run_cell(arch, shape_name, args.mesh, args.variant,
                             policy=args.policy, n_micro=args.n_micro,
                             remat=args.remat, out_dir=args.out, tag=args.tag)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((arch, shape_name, str(e)[:200]))
        if failures:
            print("FAILURES:", failures)
            raise SystemExit(1)
        return
    run_cell(args.arch, args.shape, args.mesh, args.variant,
             policy=args.policy, n_micro=args.n_micro, remat=args.remat,
             out_dir=args.out, tag=args.tag, probe3=args.probe3)


if __name__ == "__main__":
    main()
