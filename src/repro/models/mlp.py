"""SwiGLU MLP with optional int8-quantized matmuls (the Pliant lower-precision
knob): weights are quantized per-output-channel; on TPU the quantized path is
the ``kernels/int8_matmul`` Pallas kernel, on CPU the jnp reference."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec
from repro.kernels import ops as kops


def mlp_specs(cfg: ModelConfig, d_ff: int = 0):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wi_gate": ParamSpec((d, f), ("embed", "mlp")),
        "wi_up": ParamSpec((d, f), ("embed", "mlp")),
        "wo": ParamSpec((f, d), ("mlp", "embed")),
    }


def mlp(params, x, *, precision: str = "bf16"):
    # silu stays in the activation dtype: an fp32 gate leaks fp32 into the
    # backward TP all-reduces (EXPERIMENTS.md §Perf P7)
    mm = kops.matmul(precision)
    gate = jax.nn.silu(mm(x, params["wi_gate"]))
    up = mm(x, params["wi_up"])
    return mm(gate * up, params["wo"])
