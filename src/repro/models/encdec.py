"""Whisper-style encoder-decoder backbone. The conv/mel frontend is a STUB:
``input_specs()`` provides precomputed (B, 1500, d_model) frame embeddings."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ModelConfig
from repro.models.common import ParamSpec, rms_norm, stack_specs
from repro.models.blocks import block_decode, block_forward, block_specs
from repro.models.lm import (chunked_xent, init_caches, logits_fn)
from repro.approx.knobs import ApproxKnobs, PRECISE, keep_groups
from repro.models.lm import _slice_groups
from repro.dist.annotate import constrain_batch


def encdec_specs(cfg: ModelConfig):
    d = cfg.d_model
    return {
        "embed": ParamSpec((cfg.vocab_size, d), ("vocab", "embed")),
        "enc": stack_specs(block_specs(ATTN, cfg), cfg.n_encoder_layers),
        "enc_norm": ParamSpec((d,), ("embed",), init="ones"),
        "dec": {"pos0": stack_specs(block_specs(ATTN, cfg, cross=True),
                                    cfg.n_groups)},
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
    }


def encode(params, frames, cfg: ModelConfig, knobs: ApproxKnobs = PRECISE,
           *, remat: str = "full"):
    """frames: (B, F, D) stub embeddings -> (B, F, D) memory."""
    h = constrain_batch(frames.astype(params["enc_norm"].dtype))
    B, F, D = h.shape
    positions = jnp.broadcast_to(jnp.arange(F), (B, F))

    def body(h, layer_params):
        h, _ = block_forward(ATTN, layer_params, h, positions, cfg, knobs,
                             causal=False)
        return constrain_batch(h), None

    if remat in ("full", "2level", "dots"):
        body = jax.checkpoint(body)
    from repro import flags
    h, _ = jax.lax.scan(body, h, params["enc"], unroll=flags.unroll("enc"))
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def decode_hidden(params, tokens, enc_out, cfg: ModelConfig,
                  knobs: ApproxKnobs = PRECISE, *, remat: str = "full"):
    h = constrain_batch(params["embed"][tokens])
    B, S, D = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    keep = keep_groups(cfg.n_groups, knobs.layer_skip)
    groups = _slice_groups(params["dec"], keep, cfg.n_groups)

    def body(h, group_params):
        h, _ = block_forward(ATTN, group_params["pos0"], h, positions, cfg,
                             knobs, enc_out=enc_out)
        return constrain_batch(h), None

    if remat in ("full", "2level", "dots"):
        body = jax.checkpoint(body)
    from repro import flags
    h, _ = jax.lax.scan(body, h, groups, unroll=flags.unroll("groups"))
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def encdec_loss(params, batch, cfg: ModelConfig,
                knobs: ApproxKnobs = PRECISE, *, remat: str = "full",
                ep_axis=None, mesh=None, aux_coef: float = 0.0):
    """batch: {"tokens": (B,S+1), "frames": (B,F,D)}."""
    tokens, frames = batch["tokens"], batch["frames"]
    if knobs.token_drop > 0:
        b_keep = max(1, int(tokens.shape[0] * (1.0 - knobs.token_drop)))
        tokens, frames = tokens[:b_keep], frames[:b_keep]
    enc_out = encode(params, frames, cfg, knobs, remat=remat)
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    h = decode_hidden(params, inputs, enc_out, cfg, knobs, remat=remat)
    mask = jnp.ones_like(labels, jnp.float32)
    loss = chunked_xent(params, h, labels, mask, cfg)
    return loss, {"ce": loss, "aux": jnp.zeros(())}


def encdec_decode_step(params, tokens, position, caches, enc_out,
                       cfg: ModelConfig, knobs: ApproxKnobs = PRECISE):
    """One-token decode with cached decoder self-attention."""
    h = params["embed"][tokens[:, 0]][:, None, :]

    def body(h, xs):
        group_params, group_caches = xs
        h, nc, _ = block_decode(ATTN, group_params["pos0"], h, position,
                                group_caches[0], cfg, knobs, enc_out=enc_out)
        return h, (nc,)

    from repro import flags
    h, new_caches = jax.lax.scan(body, h, (params["dec"], caches),
                                 unroll=flags.unroll("groups"))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return logits_fn(params, h[:, 0], cfg), new_caches
