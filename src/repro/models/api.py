"""Family-dispatching model API used by train/serve/launch layers.

* ``model_specs(cfg)``      — full param spec tree
* ``abstract(cfg)``         — ShapeDtypeStruct params (dry-run, no allocation)
* ``init(cfg, key)``        — materialized params
* ``loss_fn(cfg)``          — (params, batch, knobs, **kw) -> (loss, metrics)
* ``input_specs(cfg, shape)``— ShapeDtypeStruct batch stand-ins per cell
* ``decode_fn(cfg)``        — one-token serve step
* ``abstract_caches(cfg, ...)`` — ShapeDtypeStruct KV/SSM caches
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.models.common import abstract_params, init_params, logical_axes
from repro.approx.knobs import ApproxKnobs, PRECISE


def model_specs(cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec_mod.encdec_specs(cfg)
    return lm_mod.lm_specs(cfg)


def abstract(cfg: ModelConfig, dtype=jnp.bfloat16):
    return abstract_params(model_specs(cfg), dtype)


def axes(cfg: ModelConfig):
    return logical_axes(model_specs(cfg))


def init(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    return init_params(model_specs(cfg), key, dtype)


def loss_fn(cfg: ModelConfig):
    if cfg.family == "encdec":
        return functools.partial(encdec_mod.encdec_loss, cfg=cfg)
    return functools.partial(lm_mod.lm_loss, cfg=cfg)


def decode_fn(cfg: ModelConfig):
    if cfg.family == "encdec":
        return functools.partial(encdec_mod.encdec_decode_step, cfg=cfg)
    return functools.partial(lm_mod.decode_step, cfg=cfg)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of one cell."""
    B, S = shape.global_batch, shape.seq_len
    tok = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    emb = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.bfloat16)
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            return {"tokens": tok((B, S + 1)),
                    "frames": emb((B, cfg.encoder_seq, cfg.d_model))}
        if cfg.family == "vlm":
            P = cfg.n_prefix_tokens
            return {"tokens": tok((B, S - P + 1)),
                    "prefix_embeds": emb((B, P, cfg.d_model))}
        return {"tokens": tok((B, S + 1))}
    # decode: one new token against a seq_len-deep cache
    out = {"tokens": tok((B, 1)), "position": tok((B,))}
    if cfg.family == "encdec":
        out["enc_out"] = emb((B, cfg.encoder_seq, cfg.d_model))
    return out


def make_inputs(cfg: ModelConfig, shape_or_specs, key=None):
    """Materialize a synthetic batch matching ``input_specs`` (smoke tests)."""
    specs = (input_specs(cfg, shape_or_specs)
             if isinstance(shape_or_specs, ShapeConfig) else shape_or_specs)
    key = key if key is not None else jax.random.PRNGKey(0)
    out = {}
    for name, s in specs.items():
        key, sub = jax.random.split(key)
        if s.dtype == jnp.int32 and name != "position":
            out[name] = jax.random.randint(sub, s.shape, 0,
                                           max(cfg.vocab_size, 2), jnp.int32)
        elif name == "position":
            out[name] = jnp.zeros(s.shape, jnp.int32)
        else:
            out[name] = jax.random.normal(sub, s.shape, jnp.float32).astype(
                s.dtype)
    return out


def abstract_caches(cfg: ModelConfig, batch: int, max_len: int,
                    quantized: bool = False, paged=None,
                    dtype=jnp.bfloat16):
    """``paged``: a ``serve.pages.PageSpec`` (or anything with page_size /
    n_pages / max_pages) selects the paged cache layout."""
    if cfg.family == "encdec":
        assert paged is None, "paged caches: decoder-only serving path"
        fn = lambda: encdec_mod.init_caches(cfg, batch, max_len, dtype,
                                            quantized=quantized)
    elif paged is not None:
        fn = lambda: lm_mod.init_paged_caches(
            cfg, batch, paged.n_pages, paged.page_size, paged.max_pages,
            dtype=dtype, quantized=quantized)
    else:
        fn = lambda: lm_mod.init_caches(cfg, batch, max_len, dtype=dtype,
                                        quantized=quantized)
    return jax.eval_shape(fn)
