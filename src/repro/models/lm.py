"""Decoder-only LM (all 10 archs route through here; whisper adds an encoder
in ``encdec.py``). Layers are scanned in *groups* — one group = one period of
``cfg.pattern`` — so HLO size is independent of depth. Zamba2's shared
attention block lives outside the scanned stack and is closed over (weights
reused every invocation, gradients accumulate through the scan).

Cross-entropy is computed in sequence chunks under ``jax.checkpoint`` so the
(B,S,V) logit tensor never materializes — required for 256k-vocab archs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ATTN, LOCAL_ATTN, MAMBA, SHARED_ATTN,
                                ModelConfig)
from repro.models.common import (ParamSpec, init_params, rms_norm, softcap,
                                 stack_specs)
from repro.models import attention as attn_mod
from repro.models import mamba2 as mamba_mod
from repro.models.blocks import block_decode, block_forward, block_specs
from repro.approx.knobs import ApproxKnobs, PRECISE, keep_groups
from repro.dist.annotate import constrain_batch, constrain_vocab


# ------------------------------------------------------------------ specs --

def lm_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    specs: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, d), ("vocab", "embed")),
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((d, cfg.vocab_size), ("embed", "vocab"))
    groups: Dict[str, Any] = {}
    for j, kind in enumerate(cfg.pattern):
        if kind == SHARED_ATTN:
            continue
        groups[f"pos{j}"] = stack_specs(block_specs(kind, cfg), cfg.n_groups)
    specs["groups"] = groups
    if SHARED_ATTN in cfg.pattern:
        specs["shared"] = block_specs(ATTN, cfg)
    return specs


def init_lm(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    return init_params(lm_specs(cfg), key, dtype)


def _slice_groups(groups, keep: Tuple[int, ...], n_groups: int):
    if len(keep) == n_groups:
        return groups
    idx = np.asarray(keep)
    return jax.tree.map(lambda p: p[idx], groups)


# ---------------------------------------------------------------- forward --

def forward_hidden(params, tokens, cfg: ModelConfig,
                   knobs: ApproxKnobs = PRECISE, *,
                   ep_axis: Optional[str] = None, mesh=None,
                   prefix_embeds: Optional[jax.Array] = None,
                   remat: str = "full"):
    """tokens: (B, S_text) -> (h (B,S,D) final-normed, aux loss).

    ``prefix_embeds``: (B, P, D) stub modality embeddings prepended (vlm).
    """
    h = params["embed"][tokens]
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    h = constrain_batch(h)
    B, S, D = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    keep = keep_groups(cfg.n_groups, knobs.layer_skip)
    groups = _slice_groups(params["groups"], keep, cfg.n_groups)
    shared = params.get("shared")

    def group_body(carry, group_params):
        h, aux = carry
        for j, kind in enumerate(cfg.pattern):
            p = shared if kind == SHARED_ATTN else group_params[f"pos{j}"]
            h, a = block_forward(kind, p, h, positions, cfg, knobs,
                                 ep_axis=ep_axis, mesh=mesh)
            aux = aux + a
        return (constrain_batch(h), aux), None

    from repro import flags
    carry0 = (h, jnp.zeros((), jnp.float32))
    if remat == "2level":
        # sqrt-depth activation memory: nested checkpointed scans store
        # ~(no + ni) layer boundaries instead of G (needed for 88-layer archs)
        no, ni = _near_sqrt_factors(len(keep))
        if no > 1:
            g2 = jax.tree.map(
                lambda p: p.reshape(no, ni, *p.shape[1:]), groups)

            def outer_body(carry, gp_outer):
                c, _ = jax.lax.scan(jax.checkpoint(group_body), carry,
                                    gp_outer, unroll=flags.unroll("groups"))
                return c, None

            (h, aux), _ = jax.lax.scan(
                jax.checkpoint(outer_body), carry0, g2,
                unroll=flags.unroll("groups_outer"))
            return rms_norm(h, params["final_norm"], cfg.norm_eps), aux
        remat = "full"                      # prime group count: fall back
    if remat == "full":
        group_body = jax.checkpoint(group_body)
    elif remat == "dots":
        group_body = jax.checkpoint(
            group_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    (h, aux), _ = jax.lax.scan(group_body, carry0, groups,
                               unroll=flags.unroll("groups"))
    return rms_norm(h, params["final_norm"], cfg.norm_eps), aux


def _near_sqrt_factors(g: int):
    """(no, ni) with no*ni == g, no as close to sqrt(g) as possible."""
    best = (1, g)
    for no in range(2, int(g ** 0.5) + 1):
        if g % no == 0:
            best = (no, g // no)
    return best


def _unembed(params, cfg: ModelConfig):
    if "unembed" in params:
        return params["unembed"]
    return params["embed"].T


def logits_fn(params, h, cfg: ModelConfig):
    """h: (..., D) -> (..., V), softcapped. Small inputs only (decode)."""
    logits = (h @ _unembed(params, cfg)).astype(jnp.float32)
    return softcap(logits, cfg.final_softcap)


def ce_chunk(s: int, target: int = 512) -> int:
    """Largest divisor of ``s`` that is <= target (CE chunk length)."""
    c = min(target, s)
    while s % c:
        c -= 1
    return c


def chunked_xent(params, h, labels, mask, cfg: ModelConfig, *,
                 chunk: int = 512):
    """Mean next-token CE without materializing full logits.

    h: (B,S,D); labels: (B,S) (already shifted); mask: (B,S) float weights.
    """
    B, S, D = h.shape
    C = ce_chunk(S, chunk)
    nc = S // C
    emb = _unembed(params, cfg)
    h = constrain_batch(h)
    hs = h.reshape(B, nc, C, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nc, C).transpose(1, 0, 2)
    ms = mask.reshape(B, nc, C).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        hc, lc, mc = xs
        hc = constrain_batch(hc)          # (B, C, D): keep batch sharded
        logits = (hc @ emb).astype(jnp.float32)
        logits = constrain_vocab(logits)  # (B, C, V): vocab stays sharded
        logits = softcap(logits, cfg.final_softcap)
        # gather-free gold logit: take_along_axis over a sharded vocab dim
        # forces GSPMD to replicate the whole logits matmul (21x FLOPs,
        # EXPERIMENTS.md §Perf); a one-hot contraction keeps vocab sharded.
        m = jnp.max(logits, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
        onehot = jax.nn.one_hot(lc, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.einsum("bcv,bcv->bc", logits, constrain_vocab(onehot))
        loss_sum, w_sum = carry
        return (loss_sum + jnp.sum((lse - gold) * mc),
                w_sum + jnp.sum(mc)), None

    from repro import flags
    (loss_sum, w_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs, ls, ms), unroll=flags.unroll("ce"))
    return loss_sum / jnp.maximum(w_sum, 1.0)


def lm_loss(params, batch, cfg: ModelConfig, knobs: ApproxKnobs = PRECISE, *,
            ep_axis: Optional[str] = None, mesh=None, remat: str = "full",
            aux_coef: float = 0.01):
    """batch: {"tokens": (B,S+1) int32, optional "prefix_embeds"}."""
    tokens = batch["tokens"]
    if knobs.token_drop > 0:                       # batch perforation
        b_keep = max(1, int(tokens.shape[0] * (1.0 - knobs.token_drop)))
        tokens = tokens[:b_keep]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    prefix = batch.get("prefix_embeds")
    if prefix is not None and knobs.token_drop > 0:
        prefix = prefix[: tokens.shape[0]]
    h, aux = forward_hidden(params, inputs, cfg, knobs, ep_axis=ep_axis,
                            mesh=mesh, prefix_embeds=prefix, remat=remat)
    if prefix is not None:
        P = prefix.shape[1]
        # prefix positions predict nothing; text position i predicts label i
        h = h[:, P:]
    mask = jnp.ones_like(labels, jnp.float32)
    loss = chunked_xent(params, h, labels, mask, cfg)
    return loss + aux_coef * aux, {"ce": loss, "aux": aux}


# ----------------------------------------------------------------- decode --

def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16, quantized: bool = False):
    """Stacked (over groups) caches, one entry per pattern position."""
    def one(kind):
        if kind == MAMBA:
            return mamba_mod.init_mamba_cache(cfg, batch, dtype)
        length = min(cfg.window, max_len) if kind == LOCAL_ATTN else max_len
        return attn_mod.init_cache(cfg, batch, length, dtype,
                                   quantized=quantized)
    def stack(tree):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_groups,) + x.shape),
            tree)
    return tuple(stack(one(kind)) for kind in cfg.pattern)


def init_paged_caches(cfg: ModelConfig, batch: int, n_pages: int,
                      page_size: int, max_pages: int, dtype=jnp.bfloat16,
                      quantized: bool = False):
    """Paged sibling of ``init_caches``: attention positions get a
    ``PagedKVCache`` over a shared physical page pool + per-slot block
    tables (``serve.pages`` owns allocation); Mamba state stays per-slot.
    Local-attention layers share the same full-length block tables and mask
    by window at attention time — pages beyond the window are dead weight a
    smarter allocator could free, but the mapping stays uniform.

    Every leaf is group-stacked (axis 0 = layer groups) like ``init_caches``
    so the decode scan consumes it unchanged; the block table is replicated
    per group (a few KiB) to keep the pytree scan-uniform.
    """
    def one(kind):
        if kind == MAMBA:
            return mamba_mod.init_mamba_cache(cfg, batch, dtype)
        return attn_mod.init_paged_cache(cfg, batch, n_pages, page_size,
                                         max_pages, dtype,
                                         quantized=quantized)
    def stack(tree):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_groups,) + x.shape),
            tree)
    return tuple(stack(one(kind)) for kind in cfg.pattern)


def decode_step(params, tokens, position, caches, cfg: ModelConfig,
                knobs: ApproxKnobs = PRECISE, *,
                ep_axis: Optional[str] = None, mesh=None,
                enc_out: Optional[jax.Array] = None, active=None,
                use_kernel: Optional[bool] = None,
                dyn_scatter: bool = False, interpret: bool = False):
    """tokens: (B,1) int32; position: (B,) absolute positions.

    Returns (logits (B,V) fp32, new_caches). ``active`` (B,) bool masks
    per-slot cache writes; ``use_kernel`` overrides the paged-attention
    kernel dispatch and ``dyn_scatter`` the paged cache-write form (see
    ``blocks.block_decode``). All hybrid layer kinds (attention pages AND
    Mamba state rows) advance inside the ONE ``lax.scan`` body below, so a
    mixed block stack is a single lowered executable per decode step.
    """
    h = params["embed"][tokens[:, 0]][:, None, :]
    shared = params.get("shared")

    def group_body(h, xs):
        group_params, group_caches = xs
        new_caches = []
        for j, kind in enumerate(cfg.pattern):
            p = shared if kind == SHARED_ATTN else group_params.get(f"pos{j}")
            h, nc, _ = block_decode(kind, p, h, position, group_caches[j],
                                    cfg, knobs, ep_axis=ep_axis, mesh=mesh,
                                    enc_out=enc_out, active=active,
                                    use_kernel=use_kernel,
                                    dyn_scatter=dyn_scatter,
                                    interpret=interpret)
            new_caches.append(nc)
        return h, tuple(new_caches)

    from repro import flags
    h, new_caches = jax.lax.scan(group_body, h,
                                 (params["groups"], caches),
                                 unroll=flags.unroll("groups"))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return logits_fn(params, h[:, 0], cfg), new_caches


def sample_token(logits, uids, draws, *, temperature: float = 0.0,
                 seed: int = 0):
    """On-device sampler under the ``(seed, uid, draw_index)`` contract.

    logits: (B, V) fp32; uids/draws: (B,) int32. Greedy argmax when
    ``temperature <= 0``; otherwise a gumbel-max categorical draw keyed by
    ``fold_in(fold_in(PRNGKey(seed), uid), draw)`` — the key depends only on
    the request identity and how many tokens it has emitted, NOT on batch
    slot, megastep width, or dispatch grouping, so any decode schedule that
    respects sequential draw indices produces the same stream.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    base = jax.random.PRNGKey(seed)

    def one(row, uid, draw):
        k = jax.random.fold_in(jax.random.fold_in(base, uid), draw)
        return jax.random.categorical(k, row / temperature)

    return jax.vmap(one)(logits, uids, draws).astype(jnp.int32)


def decode_megastep(params, cur, pos, alive, uids, draws, budget, caches,
                    cfg: ModelConfig, knobs: ApproxKnobs = PRECISE, *,
                    k: int, temperature: float = 0.0, seed: int = 0,
                    eos_id: int = -1, ep_axis: Optional[str] = None,
                    mesh=None, use_kernel: Optional[bool] = None,
                    dyn_scatter: bool = False, interpret: bool = False):
    """K fused decode steps in one executable: a ``lax.scan`` whose body IS
    ``decode_step`` plus on-device sampling and stop masking — the host
    learns K tokens per row from a single transfer.

    cur: (B,) int32 current tokens (the token whose KV gets written at
    ``pos``); pos: (B,) int32 absolute positions; alive: (B,) bool live-row
    mask (doubles as ``decode_step``'s cache-write ``active``); uids/draws:
    (B,) int32 sampler-stream coordinates; budget: (B,) int32 tokens each
    row may still emit (``max_new - len(out)`` on host).

    Per scan iteration a live row writes KV at ``pos``, samples the next
    token, and advances; a dead row is frozen — its carry is untouched and
    its output slot carries the -1 sentinel (vocab ids are >= 0). Rows die
    in-scan on EOS (when ``eos_id >= 0``) or on budget exhaustion, so an
    EOS landing mid-megastep stops that row's cache writes immediately
    without disturbing siblings. Max KV write position over the scan is
    ``pos + k - 1`` on a fully-live row — the host pre-reserves that page
    range (``PagePool.ensure_decode_range``) before dispatch.

    Returns ``(toks (B, K) int32, cur, pos, alive, draws, budget,
    new_caches)``.
    """

    def body(carry, _):
        cur, pos, alive, draws, budget, caches = carry
        logits, caches = decode_step(params, cur[:, None], pos, caches, cfg,
                                     knobs, ep_axis=ep_axis, mesh=mesh,
                                     active=alive, use_kernel=use_kernel,
                                     dyn_scatter=dyn_scatter,
                                     interpret=interpret)
        tok = sample_token(logits, uids, draws, temperature=temperature,
                           seed=seed)
        emit = alive
        out = jnp.where(emit, tok, jnp.int32(-1))
        step1 = emit.astype(jnp.int32)
        draws = draws + step1
        budget = budget - step1
        hit_eos = (out == jnp.int32(eos_id)) if eos_id >= 0 else \
            jnp.zeros_like(alive)
        alive = alive & ~hit_eos & (budget > 0)
        pos = pos + step1
        cur = jnp.where(emit, tok, cur)
        return (cur, pos, alive, draws, budget, caches), out

    carry0 = (cur, pos, alive, draws, budget, caches)
    (cur, pos, alive, draws, budget, caches), toks = jax.lax.scan(
        body, carry0, None, length=k)
    return toks.T, cur, pos, alive, draws, budget, caches
