"""GQA attention: specs, train/prefill forward (chunked, static-sliced causal),
banded sliding-window path, cross-attention, and cached decode.

The full-sequence causal path unrolls over query chunks with *static* growing
KV slices, so compiled HLO FLOPs match true causal cost (no masked-waste) —
this is the reference path the dry-run compiles. On real TPUs ``ops.flash``
dispatches to the Pallas kernel instead.

Approximation hook (Pliant "loop perforation" applied to attention): a static
``kv_keep_stride`` > 1 drops off-diagonal KV chunks with stride, cutting both
FLOPs and HBM traffic of the attention loop at bounded quality loss.
"""
from __future__ import annotations

import collections
import sys
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, apply_rope, softcap


def attn_specs(cfg: ModelConfig):
    d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return {
        "wq": ParamSpec((d, q), ("embed", "q_heads")),
        "wk": ParamSpec((d, kv), ("embed", "kv_heads")),
        "wv": ParamSpec((d, kv), ("embed", "kv_heads")),
        "wo": ParamSpec((q, d), ("q_heads", "embed")),
    }


def _split_heads(x, n_heads, head_dim):
    return x.reshape(x.shape[:-1] + (n_heads, head_dim))


def _sdpa(q, k, v, *, mask=None, cap: float = 0.0):
    """q: (B,Sq,G,R,hd) k/v: (B,Skv,G,hd). Softmax in fp32."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bsgrh,btgh->bgrst", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, cap) if cap else s
    s = s.astype(jnp.float32)
    if mask is not None:
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bgrst,btgh->bsgrh", p, v)


def _merge(o, B, Sq, q_dim):
    return o.reshape(B, Sq, q_dim)


def default_q_chunk(seq_len: int) -> int:
    """Bound the per-chunk fp32 score tile (chunk x S) at long sequences:
    32k sequences with 1024-wide chunks cost 6+ GiB of transient scores per
    layer (EXPERIMENTS.md §Perf); 256-wide chunks cap it at ~1.6 GiB."""
    if seq_len <= 8192:
        return 1024
    return 256


def attention(params, x, positions, cfg: ModelConfig, *,
              mode: str = "causal",          # causal | window | cross | full
              kv_x: Optional[jax.Array] = None,
              q_chunk: int = 0,
              kv_keep_stride: int = 1,
              rope: bool = True):
    """Full-sequence attention. x: (B,S,D). Returns (B,S,D)."""
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    G, R = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    src = x if kv_x is None else kv_x
    q = _split_heads(x @ params["wq"], cfg.n_heads, hd)
    k = _split_heads(src @ params["wk"], cfg.n_kv_heads, hd)
    v = _split_heads(src @ params["wv"], cfg.n_kv_heads, hd)
    if rope and mode != "cross":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = q.reshape(B, S, G, R, hd)

    q_chunk = q_chunk or default_q_chunk(S)
    if mode == "window":
        o = _banded(q, k, v, cfg.window, cap=cfg.attn_softcap)
    elif mode in ("cross", "full"):
        o = _sdpa(q, k, v, cap=cfg.attn_softcap)
    else:
        o = _causal_chunked(q, k, v, q_chunk=q_chunk,
                            kv_keep_stride=kv_keep_stride,
                            cap=cfg.attn_softcap)
    return _merge(o, B, S, cfg.q_dim) @ params["wo"]


def _causal_chunked(q, k, v, *, q_chunk: int, kv_keep_stride: int, cap: float):
    """Unrolled q-chunk loop; chunk i sees kv[: (i+1)*C] via static slices.

    With ``kv_keep_stride=p``: off-diagonal KV chunks are perforated — chunk i
    keeps its diagonal + previous chunk, and every p-th older chunk.
    """
    B, S, G, R, hd = q.shape
    C = min(q_chunk, S)
    assert S % C == 0, (S, C)
    n = S // C
    # positions within the full sequence for masking the diagonal chunk
    outs = []
    for i in range(n):
        qi = q[:, i * C:(i + 1) * C]
        if kv_keep_stride <= 1 or i <= 1:
            ki, vi = k[:, : (i + 1) * C], v[:, : (i + 1) * C]
            kv_pos = jnp.arange((i + 1) * C)
        else:
            # keep chunks: every `stride`-th old chunk + chunk i-1 + diagonal i
            keep = [j for j in range(i - 1) if j % kv_keep_stride == 0] + [i - 1, i]
            ki = jnp.concatenate([k[:, j * C:(j + 1) * C] for j in keep], axis=1)
            vi = jnp.concatenate([v[:, j * C:(j + 1) * C] for j in keep], axis=1)
            kv_pos = jnp.concatenate(
                [jnp.arange(j * C, (j + 1) * C) for j in keep])
        q_pos = jnp.arange(i * C, (i + 1) * C)
        mask = kv_pos[None, :] <= q_pos[:, None]           # (C, Skv_i)
        outs.append(_sdpa(qi, ki, vi,
                          mask=mask[None, None, None], cap=cap))
    return jnp.concatenate(outs, axis=1)


def _banded(q, k, v, window: int, *, cap: float):
    """Sliding-window causal attention as block-band: each W-block of queries
    attends to its own + previous KV block, masked to the exact window."""
    B, S, G, R, hd = q.shape
    W = min(window, S)
    assert S % W == 0, (S, W)
    n = S // W
    qb = q.reshape(B, n, W, G, R, hd)
    kb = k.reshape(B, n, W, G, hd)
    vb = v.reshape(B, n, W, G, hd)
    # previous block (block -1 = zeros, fully masked)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kb], axis=2)              # (B,n,2W,G,hd)
    v2 = jnp.concatenate([vprev, vb], axis=2)
    q_pos = jnp.arange(W)[:, None]                         # in-block
    kv_pos = jnp.arange(2 * W)[None, :] - W                # relative to block
    mask = (kv_pos <= q_pos) & (kv_pos > q_pos - W)
    first = jnp.arange(n)[:, None, None] > 0               # block0 has no prev
    mask = mask[None] & (first | (kv_pos[None] >= 0))
    scale = hd ** -0.5
    s = jnp.einsum("bnsgrh,bntgh->bngrst", qb, k2,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, cap) if cap else s
    s = jnp.where(mask[None, :, None, None, :, :],
                  s.astype(jnp.float32), -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bngrst,bntgh->bnsgrh", p, v2)
    return o.reshape(B, S, G, R, hd)


# ------------------------------------------------------------------ decode --

# Global static scale of the int8-quantized serving KV cache (the ``kv_quant``
# knob). Shared by decode, chunked prefill, and the engine's cache-dtype
# conversion on a variant hot-swap — all three must round identically, so
# they all go through the two helpers below.
KV_SCALE = 0.05


def quantize_kv(x, scale: float = KV_SCALE):
    return jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                    -127, 127).astype(jnp.int8)


def dequantize_kv(x, dtype, scale: float = KV_SCALE):
    return x.astype(dtype) * scale


class KVCache(NamedTuple):
    k: jax.Array          # (B, W_cache, G, hd)
    v: jax.Array
    pos: jax.Array        # (B, W_cache) absolute positions, -1 = empty
    cursor: jax.Array     # scalar int32: next write slot (ring)


def init_cache(cfg: ModelConfig, batch: int, length: int, dtype=jnp.bfloat16,
               quantized: bool = False) -> KVCache:
    hd = cfg.resolved_head_dim
    kdt = jnp.int8 if quantized else dtype
    shape = (batch, length, cfg.n_kv_heads, hd)
    return KVCache(
        k=jnp.zeros(shape, kdt), v=jnp.zeros(shape, kdt),
        pos=jnp.full((batch, length), -1, jnp.int32),
        cursor=jnp.zeros((), jnp.int32))


def decode_attention(params, x, position, cache: KVCache, cfg: ModelConfig, *,
                     window: int = 0, kv_scale: float = 0.0, rope: bool = True):
    """One-token decode. x: (B,1,D); position: (B,) absolute position.

    Returns (out (B,1,D), new_cache). Ring-buffer cache: local layers size W,
    global layers size max_seq. ``kv_scale``>0 → int8-quantized cache entries.
    """
    B, one, D = x.shape
    hd = cfg.resolved_head_dim
    G, R = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    q = _split_heads(x @ params["wq"], cfg.n_heads, hd)
    k = _split_heads(x @ params["wk"], G, hd)
    v = _split_heads(x @ params["wv"], G, hd)
    if rope:
        q = apply_rope(q, position[:, None], cfg.rope_theta)
        k = apply_rope(k, position[:, None], cfg.rope_theta)
    W = cache.k.shape[1]
    slot = cache.cursor % W
    if kv_scale:
        k_store = quantize_kv(k, kv_scale)
        v_store = quantize_kv(v, kv_scale)
    else:
        k_store, v_store = k.astype(cache.k.dtype), v.astype(cache.v.dtype)
    # one-hot masked write, NOT dynamic_update_slice: a DUS at a traced index
    # across the sequence-SHARDED cache dim makes GSPMD all-gather the whole
    # cache every step (observed 40x decode memory traffic + 0.4s collectives
    # on mistral decode_32k — EXPERIMENTS.md §Perf); the masked select is
    # elementwise over the sharded dim and partitions cleanly.
    wmask = (jnp.arange(W) == slot)
    nk = jnp.where(wmask[None, :, None, None], k_store, cache.k)
    nv = jnp.where(wmask[None, :, None, None], v_store, cache.v)
    npos = jnp.where(wmask[None, :], position[:, None], cache.pos)
    new_cache = KVCache(nk, nv, npos, cache.cursor + 1)

    kk = dequantize_kv(nk, q.dtype, kv_scale) if kv_scale else \
        nk.astype(q.dtype)
    vv = dequantize_kv(nv, q.dtype, kv_scale) if kv_scale else \
        nv.astype(q.dtype)
    qg = q.reshape(B, 1, G, R, hd)
    valid = npos >= 0
    if window:
        valid &= npos > (position[:, None] - window)
    valid &= npos <= position[:, None]
    o = _sdpa(qg, kk, vv, mask=valid[:, None, None, None, :],
              cap=cfg.attn_softcap)
    return _merge(o, B, 1, cfg.q_dim) @ params["wo"], new_cache


# ------------------------------------------------------------------- paged --

class PagedKVCache(NamedTuple):
    """Paged decode cache: entries live in a shared physical page pool and
    each batch slot maps logical pages (position // page_size) to physical
    pages through its block-table row. Physical page 0 is the reserved
    null/trash page: unmapped block entries point at it and are masked out
    of attention, and inactive decode rows scatter into it harmlessly.
    Allocation is host-side (``serve.pages.PagePool``); the jitted paths
    below only gather/scatter through the tables."""
    kp: jax.Array         # (n_pages, page_size, G, hd) physical page pool
    vp: jax.Array
    ppos: jax.Array       # (n_pages, page_size) absolute positions, -1 empty
    block: jax.Array      # (B, max_pages) int32 physical page ids, 0 = unmapped


def init_paged_cache(cfg: ModelConfig, batch: int, n_pages: int,
                     page_size: int, max_pages: int, dtype=jnp.bfloat16,
                     quantized: bool = False) -> PagedKVCache:
    hd = cfg.resolved_head_dim
    kdt = jnp.int8 if quantized else dtype
    shape = (n_pages, page_size, cfg.n_kv_heads, hd)
    return PagedKVCache(
        kp=jnp.zeros(shape, kdt), vp=jnp.zeros(shape, kdt),
        ppos=jnp.full((n_pages, page_size), -1, jnp.int32),
        block=jnp.zeros((batch, max_pages), jnp.int32))


def _page_scatter(sel, write, buf, new):
    """Scatter ``new`` rows into the page pool through a one-hot selection —
    NOT a dynamic-index scatter: indices stay on the unsharded (page, offset)
    dims as an elementwise one-hot, so a pool sharded over pages or heads
    partitions cleanly (same GSPMD hazard class as the dense ring write).

    sel: (R, n_pages, P) one-hot; write: (n_pages, P) = sel.any(0);
    buf: (n_pages, P, ...); new: (R, ...). Colliding rows (inactive decode
    slots all aimed at the trash page) sum to garbage that is never read.
    """
    scat = jnp.einsum("rnp,r...->np...", sel.astype(jnp.float32),
                      new.astype(jnp.float32))
    expand = (None,) * (buf.ndim - 2)
    return jnp.where(write[(slice(None), slice(None)) + expand],
                     scat.astype(buf.dtype), buf)


def _gather_pages(cache: PagedKVCache, block, q_positions, *, window: int):
    """Gather a block table's pages into contiguous K/V + validity mask.

    block: (B, M); q_positions: (B, C) absolute query positions. Returns
    (k (B, M*P, G, hd), v, valid (B, C, M*P)). Unmapped entries (physical
    page 0) are masked regardless of the trash page's contents.
    """
    n_pages, P = cache.ppos.shape
    B, M = block.shape
    gk = jnp.take(cache.kp, block, axis=0).reshape(B, M * P, *cache.kp.shape[2:])
    gv = jnp.take(cache.vp, block, axis=0).reshape(B, M * P, *cache.vp.shape[2:])
    gpos = jnp.take(cache.ppos, block, axis=0).reshape(B, M * P)
    mapped = jnp.repeat(block != 0, P, axis=1)            # (B, M*P)
    valid = mapped[:, None, :] & (gpos[:, None, :] >= 0)
    valid &= gpos[:, None, :] <= q_positions[:, :, None]
    if window:
        valid &= gpos[:, None, :] > q_positions[:, :, None] - window
    return gk, gv, gpos, valid


# Trace-time audit of which paged-decode path each compile took, keyed by
# dispatch outcome (kernel_sharded / gather_mesh / kernel_single /
# gather_single). Counts bump while TRACING, so after a jitted step is
# compiled the counter tells tests which path is in the executable — the
# gather fallback under a mesh is otherwise invisible from outside.
DISPATCH_COUNTS: "collections.Counter[str]" = collections.Counter()

_GATHER_WARNED = set()


def _warn_gather(reason: str) -> None:
    """One line per distinct reason: a mesh silently paying O(slots x
    max_len) gather traffic was the regression class this replaces."""
    if reason in _GATHER_WARNED:
        return
    _GATHER_WARNED.add(reason)
    print("repro: paged decode under a mesh is taking the GSPMD dense "
          f"gather path — {reason}; the fused kernel is not sharded, so "
          "decode HBM traffic is O(slots x max_len) per device",
          file=sys.stderr)


def explain_dispatch(cfg: ModelConfig, mesh, *, batch_slots: int,
                     n_pages: int = 0,
                     use_kernel: Optional[bool] = None,
                     megastep_k: int = 0) -> str:
    """One-line description of the paged-decode path this configuration
    dispatches to (surfaced by ``launch/serve.py`` at startup).
    ``megastep_k > 0`` notes that the decode cell runs inside a fused
    K-step scan (one executable dispatch per K tokens) — the attention
    dispatch decision itself is identical per scan iteration."""
    from repro.kernels import ops as kops
    if use_kernel is None:
        use_kernel = kops._on_tpu()
    mega = (f", inside a fused {megastep_k}-token megastep scan"
            if megastep_k > 0 else "")
    if mesh is None:
        return (f"paged decode: fused Pallas kernel, single device{mega}"
                if use_kernel else
                "paged decode: dense gather reference, single device "
                f"(kernel off: not on TPU){mega}")
    if not use_kernel:
        return ("paged decode: GSPMD dense gather under mesh "
                f"(kernel off: not on TPU){mega}")
    from repro.dist.sharding import paged_decode_plan
    plan, reason = paged_decode_plan(cfg, mesh, batch_slots, n_pages)
    if plan is not None:
        heads = (f"kv_heads over {plan.kv_head_axis!r}"
                 if plan.kv_head_axis else "kv_heads replicated")
        return ("paged decode: fused kernel shard_map'd over "
                f"{plan.batch_axes!r} ({plan.n_shards} slot-affinity "
                f"shards, {heads}){mega}")
    return ("paged decode: GSPMD dense gather FALLBACK under mesh — "
            f"{reason}{mega}")


def _warn_prefill(reason: str) -> None:
    """Prefill's mirror of ``_warn_gather``: a mesh silently running every
    admission chunk's attention whole on each device is the idle-7-of-8
    regression class the ring replaces."""
    key = "prefill:" + reason
    if key in _GATHER_WARNED:
        return
    _GATHER_WARNED.add(key)
    print("repro: chunked-prefill admission under a mesh is taking the "
          f"GSPMD unsharded path — {reason}; each chunk's attention runs "
          "whole per device (no sequence parallelism)", file=sys.stderr)


def _prefill_ring_plan(cfg: ModelConfig, mesh, chunk_len: int,
                       use_kernel: Optional[bool]):
    """The (plan, reason) both chunk cells dispatch on, with the trace-time
    counter bump (ring_prefill / prefill_gather_mesh / prefill_single) and
    the loud fallback warning — prefill's mirror of the paged-decode
    dispatch block."""
    from repro.kernels import ops as kops
    if mesh is None:
        DISPATCH_COUNTS["prefill_single"] += 1
        return None, "no mesh (single device)"
    if use_kernel is None:
        use_kernel = kops._on_tpu()
    if not use_kernel:
        plan, reason = None, "kernel off: not on TPU"
    else:
        from repro.dist.sharding import prefill_plan
        plan, reason = prefill_plan(cfg, mesh, chunk_len)
    if plan is not None:
        DISPATCH_COUNTS["ring_prefill"] += 1
        return plan, ""
    DISPATCH_COUNTS["prefill_gather_mesh"] += 1
    _warn_prefill(reason)
    return None, reason


def explain_prefill_dispatch(cfg: ModelConfig, mesh, *, chunk_len: int,
                             use_kernel: Optional[bool] = None) -> str:
    """One-line description of the chunked-prefill admission path this
    configuration dispatches to (surfaced next to ``explain_dispatch`` in
    the ``launch/serve.py`` startup banner)."""
    from repro.kernels import ops as kops
    if use_kernel is None:
        use_kernel = kops._on_tpu()
    if mesh is None:
        return "chunked prefill: whole-chunk admission cell, single device"
    if not use_kernel:
        return ("chunked prefill: GSPMD unsharded admission under mesh "
                "(kernel off: not on TPU)")
    from repro.dist.sharding import prefill_plan
    plan, reason = prefill_plan(cfg, mesh, chunk_len)
    if plan is not None:
        heads = (f"kv_heads over {plan.kv_head_axis!r}"
                 if plan.kv_head_axis else "kv_heads replicated")
        return ("chunked prefill: ring attention shard_map'd over "
                f"{plan.seq_axis!r} ({plan.n_shards} sequence shards, "
                f"{heads})")
    return ("chunked prefill: GSPMD unsharded admission FALLBACK under "
            f"mesh — {reason}")


def _flat_axis_index(mesh, axes):
    """Linear shard index over (possibly several) mesh axes, major-first —
    matches how GSPMD linearizes a dim sharded over an axis tuple."""
    flat = axes if isinstance(axes, tuple) else (axes,)
    idx = None
    for a in flat:
        i = jax.lax.axis_index(a)
        idx = i if idx is None else idx * mesh.shape[a] + i
    return idx


def _sharded_write_attend(q, k_store, v_store, position, active,
                          cache: PagedKVCache, mesh, plan, *, window: int,
                          kv_scale: float, cap: float, interpret: bool):
    """ONE shard_map region: slot-affinity dynamic cache write + the fused
    Pallas kernel, zero collectives.

    Under the slot-affinity layout (``serve.pages``: slot ``s``'s pages all
    live in its shard's contiguous page range) every device holds exactly
    the pages its slots' block tables reference, so inside the region the
    global page ids rebase to local ones (``pid - shard * chunk``; the 0
    sentinel maps to the shard's local null page 0) and both the
    dynamic-index ``.at[page, offset].set`` write — illegal under GSPMD on a
    sharded page dim — and the scalar-prefetch kernel grid become plain
    single-device programs per shard. Inactive rows write into the local
    null page (never read). q: (B, G, R, hd); k_store/v_store: (B, G, hd)
    at cache dtype. Returns (o (B, G, R, hd), new PagedKVCache).
    """
    from jax.sharding import PartitionSpec as P
    from repro.dist import compat
    from repro.kernels.paged_attention import paged_attention_impl
    b, g = plan.batch_axes, plan.kv_head_axis
    n_pages, Pg = cache.ppos.shape
    chunk = n_pages // plan.n_shards

    def inner(q_l, k_l, v_l, pos_l, act_l, kp_l, vp_l, ppos_l, block_l):
        base = _flat_axis_index(mesh, b) * chunk
        lblock = jnp.where(block_l == 0, 0, block_l - base)
        phys = jnp.take_along_axis(lblock, (pos_l // Pg)[:, None],
                                   axis=1)[:, 0]
        tgt = jnp.where(act_l, phys, 0)
        off = pos_l % Pg
        nkp = kp_l.at[tgt, off].set(k_l)
        nvp = vp_l.at[tgt, off].set(v_l)
        nppos = ppos_l.at[tgt, off].set(pos_l)
        o = paged_attention_impl(q_l, nkp, nvp, nppos, lblock, pos_l,
                                 window=window, kv_scale=kv_scale, cap=cap,
                                 interpret=interpret)
        return o, nkp, nvp, nppos

    q_spec = P(b, g, None, None)
    kv_spec = P(b, None, g, None)
    fn = compat.shard_map(
        inner, mesh=mesh,
        in_specs=(q_spec, P(b, g, None), P(b, g, None), P(b), P(b),
                  kv_spec, kv_spec, P(b, None), P(b, None)),
        out_specs=(q_spec, kv_spec, kv_spec, P(b, None)),
        check_vma=False)
    o, nkp, nvp, nppos = fn(q, k_store, v_store, position, active,
                            cache.kp, cache.vp, cache.ppos, cache.block)
    return o, PagedKVCache(nkp, nvp, nppos, cache.block)


def paged_decode_attention(params, x, position, cache: PagedKVCache,
                           cfg: ModelConfig, *, window: int = 0,
                           kv_scale: float = 0.0, active=None,
                           use_kernel: Optional[bool] = None,
                           interpret: bool = False,
                           dyn_scatter: bool = False, mesh=None):
    """One-token decode against the paged pool. x: (B,1,D); position: (B,).

    The new K/V entry scatters into the slot's private tail page (host-side
    allocation guarantees it is mapped and unshared before the step runs);
    attention reads every mapped page through the block table masked by
    position/window — the paged sibling of ``decode_attention``.

    ``active`` (B,) bool masks the cache WRITE per slot: rows of a decode
    batch whose slot has no live request (e.g. an admission prefilling in
    the background between decode steps) must not scatter garbage into
    their mapped pages or ppos rows. Inactive rows' outputs are garbage the
    engine never reads.

    ``use_kernel`` selects the fused Pallas kernel
    (``kernels.paged_attention``): pages stream HBM->VMEM in place via the
    block table with online-softmax accumulation — O(live pages) traffic.
    Defaults to the kernel on TPU; the ``_gather_pages`` + ``_sdpa`` path
    below is the interpret/reference fallback (and the GSPMD path for
    sharded pools).

    ``dyn_scatter`` replaces the one-hot masked write (O(n_pages * P) work
    per entry) with a dynamic-index ``.at[page, offset].set`` — O(1) per
    entry. Safe ONLY for unsharded pools: under GSPMD a dynamic scatter on
    a partitioned page dim lowers to all-gather traffic, which is exactly
    what the one-hot form avoids. Inactive rows are redirected to the null
    page instead of suppressed, an equivalent no-op (page 0 is never read).

    ``mesh`` + kernel requested: when ``dist.sharding.paged_decode_plan``
    finds a slot-affinity layout, write AND kernel both run inside ONE
    ``shard_map`` region (``_sharded_write_attend``) — each device's kernel
    invocation prefetches only its shard's pages, so multi-device decode
    runs at single-device speed per shard. Otherwise the gather fallback
    below is taken and ``_warn_gather`` says so (once per reason).
    """
    from repro.kernels import ops as kops
    from repro.kernels.paged_attention import paged_attention
    B, one, D = x.shape
    hd = cfg.resolved_head_dim
    G, R = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    q = _split_heads(x @ params["wq"], cfg.n_heads, hd)
    k = _split_heads(x @ params["wk"], G, hd)
    v = _split_heads(x @ params["wv"], G, hd)
    q = apply_rope(q, position[:, None], cfg.rope_theta)
    k = apply_rope(k, position[:, None], cfg.rope_theta)
    if kv_scale:
        k_store = quantize_kv(k, kv_scale)
        v_store = quantize_kv(v, kv_scale)
    else:
        k_store = k.astype(cache.kp.dtype)
        v_store = v.astype(cache.vp.dtype)
    n_pages, P = cache.ppos.shape
    if use_kernel is None:
        use_kernel = kops._on_tpu()
    if mesh is not None:
        if use_kernel:
            from repro.dist.sharding import paged_decode_plan
            plan, reason = paged_decode_plan(cfg, mesh, B, n_pages)
        else:
            plan, reason = None, "use_kernel=False (kernel disabled)"
        if plan is not None:
            DISPATCH_COUNTS["kernel_sharded"] += 1
            act = (active if active is not None
                   else jnp.ones((B,), jnp.bool_))
            o, new_cache = _sharded_write_attend(
                q[:, 0].reshape(B, G, R, hd), k_store[:, 0], v_store[:, 0],
                position, act, cache, mesh, plan, window=window,
                kv_scale=kv_scale, cap=cfg.attn_softcap, interpret=interpret)
            return o.reshape(B, 1, cfg.q_dim) @ params["wo"], new_cache
        DISPATCH_COUNTS["gather_mesh"] += 1
        _warn_gather(reason)
        use_kernel = False
    else:
        DISPATCH_COUNTS["kernel_single" if use_kernel
                        else "gather_single"] += 1
    phys = jnp.take_along_axis(cache.block, (position // P)[:, None],
                               axis=1)[:, 0]              # (B,)
    if dyn_scatter:
        tgt = phys if active is None else jnp.where(active, phys, 0)
        off = position % P
        nkp = cache.kp.at[tgt, off].set(k_store[:, 0])
        nvp = cache.vp.at[tgt, off].set(v_store[:, 0])
        nppos = cache.ppos.at[tgt, off].set(position)
    else:
        sel = ((jnp.arange(n_pages)[None, :, None] == phys[:, None, None])
               & (jnp.arange(P)[None, None, :]
                  == (position % P)[:, None, None]))
        if active is not None:
            sel &= active[:, None, None]
        write = sel.any(axis=0)
        nkp = _page_scatter(sel, write, cache.kp, k_store[:, 0])
        nvp = _page_scatter(sel, write, cache.vp, v_store[:, 0])
        nppos = _page_scatter(sel, write, cache.ppos, position)
    new_cache = PagedKVCache(nkp, nvp, nppos, cache.block)

    if use_kernel:
        qk = q[:, 0].reshape(B, G, R, hd)
        o = paged_attention(qk, nkp, nvp, nppos, cache.block, position,
                            window=window, kv_scale=kv_scale,
                            cap=cfg.attn_softcap, interpret=interpret)
        return o.reshape(B, 1, cfg.q_dim) @ params["wo"], new_cache

    kk, vv, _, valid = _gather_pages(new_cache, cache.block, position[:, None],
                                     window=window)
    dq = (lambda a: dequantize_kv(a, q.dtype, kv_scale)) if kv_scale else \
        (lambda a: a.astype(q.dtype))
    qg = q.reshape(B, 1, G, R, hd)
    o = _sdpa(qg, dq(kk), dq(vv), mask=valid[:, None, None],
              cap=cfg.attn_softcap)
    return _merge(o, B, 1, cfg.q_dim) @ params["wo"], new_cache


def paged_chunk_attention(params, x, positions, cache: PagedKVCache,
                          cfg: ModelConfig, slot, *, window: int = 0,
                          kv_scale: float = 0.0, dyn_scatter: bool = False,
                          mesh=None, use_kernel: Optional[bool] = None,
                          interpret: bool = False):
    """C-token prompt-chunk step for ONE slot of the paged pool (chunked
    admission). x: (1,C,D); positions: (1,C); ``slot`` is a traced scalar —
    one executable per chunk length serves every slot and every chunk.

    Scatters the chunk's K/V into the slot's (pre-allocated, private) pages,
    then attends over every mapped page — the chunk's own entries included,
    causally masked by position. Prefix-shared pages are simply already
    present in the block row; chunks the engine skipped on a prefix hit were
    never run.

    ``mesh`` + kernel requested: when ``dist.sharding.prefill_plan`` finds a
    sequence layout, the attend runs in ``kernels.ring_attention``. The
    slot's pages live on ONE shard under slot affinity, so the block-table
    gather stays *outside* the ring region — GSPMD moves each mapped page
    once into the ring's sequence-sharded layout (the per-shard rebase: each
    shard holds a contiguous slice of the gathered context and its absolute
    positions) — and the dominant O(C x L) attention compute/bytes then
    split 1/n_shards per device. Unmapped block entries fold into the
    position lane as -1 before the ring, which masks them identically to
    ``_gather_pages``. Fallback is the whole-chunk gather + ``_sdpa``.
    """
    from repro.dist.annotate import constrain_replicated
    B, C, D = x.shape
    hd = cfg.resolved_head_dim
    G, R = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    # gather chunk Q/K/V before rope (0.4.x TP-sharded head_dim hazard,
    # see chunk_decode_attention)
    q = constrain_replicated(_split_heads(x @ params["wq"], cfg.n_heads, hd))
    k = constrain_replicated(_split_heads(x @ params["wk"], G, hd))
    v = constrain_replicated(_split_heads(x @ params["wv"], G, hd))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if kv_scale:
        k_store = quantize_kv(k, kv_scale)
        v_store = quantize_kv(v, kv_scale)
    else:
        k_store = k.astype(cache.kp.dtype)
        v_store = v.astype(cache.vp.dtype)
    n_pages, P = cache.ppos.shape
    brow = jnp.take(cache.block, slot, axis=0)            # (M,)
    pos_c = positions[0]                                  # (C,)
    phys = jnp.take(brow, pos_c // P)                     # (C,)
    if dyn_scatter:
        # dynamic-index write (unsharded pools only — see
        # paged_decode_attention): chunk positions are distinct, so the
        # per-token targets never collide
        off = pos_c % P
        nkp = cache.kp.at[phys, off].set(k_store[0])
        nvp = cache.vp.at[phys, off].set(v_store[0])
        nppos = cache.ppos.at[phys, off].set(pos_c)
    else:
        sel = ((jnp.arange(n_pages)[None, :, None] == phys[:, None, None])
               & (jnp.arange(P)[None, None, :]
                  == (pos_c % P)[:, None, None]))
        write = sel.any(axis=0)
        nkp = _page_scatter(sel, write, cache.kp, k_store[0])
        nvp = _page_scatter(sel, write, cache.vp, v_store[0])
        nppos = _page_scatter(sel, write, cache.ppos, pos_c)
    new_cache = PagedKVCache(nkp, nvp, nppos, cache.block)

    plan, _ = _prefill_ring_plan(cfg, mesh, C, use_kernel)
    if plan is not None:
        from repro.kernels.ring_attention import ring_chunk_attention
        M = brow.shape[0]
        gk = jnp.take(nkp, brow[None], axis=0).reshape(B, M * P, G, hd)
        gv = jnp.take(nvp, brow[None], axis=0).reshape(B, M * P, G, hd)
        gpos = jnp.take(nppos, brow[None], axis=0).reshape(B, M * P)
        mapped = jnp.repeat(brow[None] != 0, P, axis=1)
        kv_pos = jnp.where(mapped, gpos, -1)
        o = ring_chunk_attention(q.reshape(B, C, G, R, hd), gk, gv,
                                 positions, kv_pos, mesh=mesh, plan=plan,
                                 window=window, cap=cfg.attn_softcap,
                                 kv_scale=kv_scale, interpret=interpret)
        return _merge(o, B, C, cfg.q_dim) @ params["wo"], new_cache

    kk, vv, _, valid = _gather_pages(new_cache, brow[None], positions,
                                     window=window)
    dq = (lambda a: dequantize_kv(a, q.dtype, kv_scale)) if kv_scale else \
        (lambda a: a.astype(q.dtype))
    qg = q.reshape(B, C, G, R, hd)
    o = _sdpa(qg, dq(kk), dq(vv), mask=valid[:, None, None],
              cap=cfg.attn_softcap)
    return _merge(o, B, C, cfg.q_dim) @ params["wo"], new_cache


def chunk_decode_attention(params, x, positions, cache: KVCache,
                           cfg: ModelConfig, *, window: int = 0,
                           kv_scale: float = 0.0, mesh=None,
                           use_kernel: Optional[bool] = None,
                           interpret: bool = False):
    """C-token prompt-chunk step against an existing ring cache.

    x: (B,C,D); positions: (B,C) absolute. The chunk attends to every valid
    cache entry PLUS itself (causal within the chunk), then the last
    ``min(C, W)`` chunk entries are written into the ring at the slots the
    token-by-token warmup would have used — so decode continues bit-compatibly
    from ``cache.cursor + C``. The generalization of ``decode_attention`` to
    C tokens (C=1 reduces to it); the chunked-prefill admission path.

    ``mesh`` + kernel requested: when ``dist.sharding.prefill_plan`` finds a
    sequence layout, the attend runs in ``kernels.ring_attention`` — queries
    resident per shard, the [cache; chunk] context rotating by ``ppermute``
    with the online-softmax state carried across hops — so admission compute
    scales 1/n_shards per device. Otherwise the whole-chunk ``_sdpa`` below
    is taken and ``_warn_prefill`` says so (once per reason).
    """
    from repro.dist.annotate import constrain_replicated
    B, C, D = x.shape
    hd = cfg.resolved_head_dim
    G, R = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    # gather the chunk Q/K/V before rope: the 0.4.x partitioner miscompiles
    # split+concat over a TP-sharded head_dim (wrong values, not just slow);
    # these are only a few tokens wide, so the gather is cheap
    q = constrain_replicated(_split_heads(x @ params["wq"], cfg.n_heads, hd))
    k = constrain_replicated(_split_heads(x @ params["wk"], G, hd))
    v = constrain_replicated(_split_heads(x @ params["wv"], G, hd))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if kv_scale:
        k_store = quantize_kv(k, kv_scale)
        v_store = quantize_kv(v, kv_scale)
    else:
        k_store, v_store = k.astype(cache.k.dtype), v.astype(cache.v.dtype)

    # ring write: last n_keep chunk entries land at (cursor + C - n_keep + j)
    # mod W — identical slots to C successive decode-step writes. Expressed
    # as a one-hot contraction, NOT jnp.roll/dynamic-slice at a traced shift:
    # the dynamic-slice lowering misplaces entries under GSPMD once the chunk
    # K/V are TP-sharded (same hazard as decode_attention's masked write).
    W = cache.k.shape[1]
    n_keep = min(C, W)
    dest = (cache.cursor + C - n_keep + jnp.arange(n_keep)) % W
    sel = dest[:, None] == jnp.arange(W)[None, :]        # (n_keep, W) one-hot
    wmask = sel.any(axis=0)

    def ring_write(buf, chunk_tail):
        scat = jnp.einsum("jw,bj...->bw...", sel.astype(jnp.float32),
                          chunk_tail.astype(jnp.float32))
        expand = (None,) * (buf.ndim - 2)
        return jnp.where(wmask[(None, slice(None)) + expand],
                         scat.astype(buf.dtype), buf)

    nk = ring_write(cache.k, k_store[:, C - n_keep:])
    nv = ring_write(cache.v, v_store[:, C - n_keep:])
    npos = ring_write(cache.pos, positions[:, C - n_keep:])
    new_cache = KVCache(nk, nv, npos, cache.cursor + C)

    # attend over [prior ring entries; full chunk] so intra-chunk tokens are
    # visible even when C exceeds the ring (local layers attend pre-eviction,
    # exactly like the full-sequence banded path).
    plan, _ = _prefill_ring_plan(cfg, mesh, C, use_kernel)
    if plan is not None:
        from repro.kernels.ring_attention import ring_chunk_attention
        kk_s = jnp.concatenate([cache.k, k_store], axis=1)  # storage dtype
        vv_s = jnp.concatenate([cache.v, v_store], axis=1)
        kv_pos = jnp.concatenate([cache.pos, positions], axis=1)
        o = ring_chunk_attention(q.reshape(B, C, G, R, hd), kk_s, vv_s,
                                 positions, kv_pos, mesh=mesh, plan=plan,
                                 window=window, cap=cfg.attn_softcap,
                                 kv_scale=kv_scale, interpret=interpret)
        return _merge(o, B, C, cfg.q_dim) @ params["wo"], new_cache
    dq = (lambda a: dequantize_kv(a, q.dtype, kv_scale)) if kv_scale else \
        (lambda a: a.astype(q.dtype))
    kk = jnp.concatenate([dq(cache.k), dq(k_store)], axis=1)
    vv = jnp.concatenate([dq(cache.v), dq(v_store)], axis=1)
    kv_pos = jnp.concatenate([cache.pos, positions], axis=1)   # (B, W+C)
    valid = kv_pos[:, None, :] >= 0
    valid &= kv_pos[:, None, :] <= positions[:, :, None]
    if window:
        valid &= kv_pos[:, None, :] > positions[:, :, None] - window
    qg = q.reshape(B, C, G, R, hd)
    o = _sdpa(qg, kk, vv, mask=valid[:, None, None], cap=cfg.attn_softcap)
    return _merge(o, B, C, cfg.q_dim) @ params["wo"], new_cache
