"""Param-spec system, norms, RoPE, and numeric helpers.

Every module declares its parameters as a pytree of ``ParamSpec`` (shape +
logical axis names). The same spec tree serves three consumers:

* ``init_params``      — materialize random weights (CPU smoke / examples),
* ``abstract_params``  — ``ShapeDtypeStruct`` stand-ins (dry-run: NO allocation),
* ``dist.sharding``    — logical-axis → mesh-axis rules → ``NamedSharding``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Any, ...]           # logical axis name (or None) per dim
    init: str = "normal"            # normal | zeros | ones | ssm_a | ssm_dt

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_tree_map(fn: Callable[[ParamSpec], Any], tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def stack_specs(tree, n: int, axis_name: str = "layers"):
    """Prepend a stacking dim of size ``n`` to every spec (for lax.scan)."""
    return spec_tree_map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init), tree)


def abstract_params(spec_tree, dtype=jnp.bfloat16):
    def mk(s: ParamSpec):
        dt = jnp.float32 if s.init in ("ssm_a", "ssm_dt") else dtype
        return jax.ShapeDtypeStruct(s.shape, dt)
    return spec_tree_map(mk, spec_tree)


def logical_axes(spec_tree):
    return spec_tree_map(lambda s: s.axes, spec_tree)


def init_params(spec_tree, key, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for s, k in zip(leaves, keys):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, dtype))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, dtype))
        elif s.init == "ssm_a":        # A_log init: log(uniform[1,16])
            out.append(jnp.log(jax.random.uniform(
                k, s.shape, jnp.float32, 1.0, 16.0)))
        elif s.init == "ssm_dt":       # dt bias: softplus^-1(uniform[1e-3,1e-1])
            dt = jnp.exp(jax.random.uniform(
                k, s.shape, jnp.float32) * (np.log(0.1) - np.log(1e-3))
                + np.log(1e-3))
            out.append(jnp.log(jnp.expm1(dt)))
        else:                          # truncated-normal, fan-in scaled
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            std = 1.0 / np.sqrt(max(fan_in, 1))
            out.append((jax.random.truncated_normal(
                k, -2.0, 2.0, s.shape, jnp.float32) * std).astype(dtype))
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------- numerics --

import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x, scale, eps: float = 1e-6):
    """RMSNorm with fp32 restricted to (B,S,1) reductions in BOTH directions.

    A plain fp32-upcast implementation leaks fp32 through autodiff into the
    residual-stream gradients, which GSPMD then all-reduces as fp32 payloads
    — 2x the TP activation wire bytes (EXPERIMENTS.md §Perf, mistral train
    iteration P7). The hand-written VJP keeps every (B,S,D) tensor in the
    activation dtype; only rowwise statistics are fp32.
    """
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def _rms_fwd(x, scale, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv32 = jax.lax.rsqrt(var + eps)                       # (B,S,1) f32
    return x * inv32.astype(x.dtype) * scale.astype(x.dtype), (x, inv32,
                                                               scale)


def _rms_bwd(eps, res, dy):
    x, inv32, scale = res
    d = x.shape[-1]
    dyg = dy * scale.astype(dy.dtype)                      # (B,S,D) low-prec
    # rowwise fp32 statistic: sum(dyg * x)
    t = jnp.sum((dyg * x).astype(jnp.float32), axis=-1, keepdims=True)
    coef = (inv32 ** 3 * (t / d)).astype(x.dtype)          # (B,S,1)
    dx = dyg * inv32.astype(dy.dtype) - x * coef
    dscale = jnp.sum((dy * x).astype(jnp.float32)
                     * inv32, axis=tuple(range(dy.ndim - 1)))
    return dx, dscale.astype(scale.dtype)


rms_norm.defvjp(_rms_fwd, _rms_bwd)


def softcap(x, cap: float):
    """Gemma2 logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S).

    Angles/sin/cos in fp32 (position precision), the rotation MULTIPLY in the
    activation dtype: an fp32 multiply leaks fp32 into the backward pass and
    doubles the TP partial-sum all-reduce payloads (EXPERIMENTS.md §Perf P7).
    """
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    sin = jnp.sin(ang).astype(x.dtype)
    cos = jnp.cos(ang).astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)
