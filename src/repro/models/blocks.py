"""Per-kind transformer blocks (pre-norm residual) and their param specs."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL_ATTN, MAMBA, SHARED_ATTN, ModelConfig
from repro.models.common import ParamSpec, rms_norm
from repro.models import attention as attn_mod
from repro.models import mamba2 as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.approx.knobs import ApproxKnobs, PRECISE


def block_specs(kind: str, cfg: ModelConfig, *, cross: bool = False):
    d = cfg.d_model
    if kind == MAMBA:
        return {"norm": ParamSpec((d,), ("embed",), init="ones"),
                "mixer": mamba_mod.mamba_specs(cfg)}
    # attention-family block
    s = {"norm_attn": ParamSpec((d,), ("embed",), init="ones"),
         "attn": attn_mod.attn_specs(cfg),
         "norm_mlp": ParamSpec((d,), ("embed",), init="ones")}
    if cross:
        s["norm_cross"] = ParamSpec((d,), ("embed",), init="ones")
        s["cross"] = attn_mod.attn_specs(cfg)
    if cfg.moe is not None and kind in (ATTN, LOCAL_ATTN):
        s["moe"] = moe_mod.moe_specs(cfg)
    else:
        s["mlp"] = mlp_mod.mlp_specs(cfg)
    return s


def block_forward(kind: str, params, h, positions, cfg: ModelConfig,
                  knobs: ApproxKnobs = PRECISE, *,
                  ep_axis: Optional[str] = None, mesh=None,
                  enc_out: Optional[jax.Array] = None,
                  causal: bool = True):
    """Full-sequence block. Returns (h, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    prec = knobs.matmul_precision
    if kind == MAMBA:
        h = h + mamba_mod.mamba_mixer(params["mixer"],
                                      rms_norm(h, params["norm"], cfg.norm_eps),
                                      cfg, precision=prec)
        return h, aux
    mode = ("window" if kind == LOCAL_ATTN else
            ("causal" if causal else "full"))
    h = h + attn_mod.attention(
        params["attn"], rms_norm(h, params["norm_attn"], cfg.norm_eps),
        positions, cfg, mode=mode, kv_keep_stride=knobs.kv_keep_stride)
    if enc_out is not None:
        h = h + attn_mod.attention(
            params["cross"], rms_norm(h, params["norm_cross"], cfg.norm_eps),
            positions, cfg, mode="cross", kv_x=enc_out)
    hn = rms_norm(h, params["norm_mlp"], cfg.norm_eps)
    if "moe" in params:
        y, aux = moe_mod.moe(params["moe"], hn, cfg,
                             top_k=knobs.topk_override, precision=prec,
                             ep_axis=ep_axis, mesh=mesh)
        h = h + y
    else:
        h = h + mlp_mod.mlp(params["mlp"], hn, precision=prec)
    return h, aux


def block_prefill(kind: str, params, h, positions, cache, cfg: ModelConfig,
                  knobs: ApproxKnobs = PRECISE, *,
                  ep_axis: Optional[str] = None, mesh=None,
                  use_kernel: Optional[bool] = None, interpret: bool = False):
    """C-token prompt-chunk step against an existing cache.

    h: (B,C,D); positions: (B,C) absolute. Returns (h, new_cache, aux) — the
    chunk-sized sibling of ``block_decode`` (serving admission path). Under
    a ``mesh`` the attention runs ring-sequence-parallel when
    ``dist.sharding.prefill_plan`` allows (``use_kernel``/``interpret``
    mirror ``block_decode``'s kernel dispatch knobs)."""
    aux = jnp.zeros((), jnp.float32)
    prec = knobs.matmul_precision
    if kind == MAMBA:
        y, new_cache = mamba_mod.mamba_prefill(
            params["mixer"], rms_norm(h, params["norm"], cfg.norm_eps),
            cache, cfg, precision=prec)
        return h + y, new_cache, aux
    window = cfg.window if kind == LOCAL_ATTN else 0
    kv_scale = attn_mod.KV_SCALE if knobs.kv_quant else 0.0
    y, new_cache = attn_mod.chunk_decode_attention(
        params["attn"], rms_norm(h, params["norm_attn"], cfg.norm_eps),
        positions, cache, cfg, window=window, kv_scale=kv_scale, mesh=mesh,
        use_kernel=use_kernel, interpret=interpret)
    h = h + y
    hn = rms_norm(h, params["norm_mlp"], cfg.norm_eps)
    if "moe" in params:
        y, aux = moe_mod.moe(params["moe"], hn, cfg,
                             top_k=knobs.topk_override, precision=prec,
                             ep_axis=ep_axis, mesh=mesh)
        h = h + y
    else:
        h = h + mlp_mod.mlp(params["mlp"], hn, precision=prec)
    return h, new_cache, aux


def block_prefill_paged(kind: str, params, h, positions, cache,
                        cfg: ModelConfig, knobs: ApproxKnobs = PRECISE, *,
                        slot, ep_axis: Optional[str] = None, mesh=None,
                        dyn_scatter: bool = False,
                        use_kernel: Optional[bool] = None,
                        interpret: bool = False):
    """Paged sibling of ``block_prefill``: one slot's prompt chunk against
    the shared page pool / per-slot Mamba rows. h: (1,C,D); ``slot`` traced.
    """
    aux = jnp.zeros((), jnp.float32)
    prec = knobs.matmul_precision
    if kind == MAMBA:
        # slice the slot's state row out, run the chunk, scatter it back —
        # a masked select, keeping every leaf's batch dim intact for GSPMD
        row = jax.tree.map(lambda x: jnp.take(x, slot[None], axis=0), cache)
        y, row2 = mamba_mod.mamba_prefill(
            params["mixer"], rms_norm(h, params["norm"], cfg.norm_eps),
            row, cfg, precision=prec)
        B = cache.state.shape[0]
        smask = jnp.arange(B) == slot
        new_cache = jax.tree.map(
            lambda old, new: jnp.where(
                smask.reshape((B,) + (1,) * (old.ndim - 1)), new, old),
            cache, row2)
        return h + y, new_cache, aux
    window = cfg.window if kind == LOCAL_ATTN else 0
    kv_scale = attn_mod.KV_SCALE if knobs.kv_quant else 0.0
    y, new_cache = attn_mod.paged_chunk_attention(
        params["attn"], rms_norm(h, params["norm_attn"], cfg.norm_eps),
        positions, cache, cfg, slot, window=window, kv_scale=kv_scale,
        dyn_scatter=dyn_scatter, mesh=mesh, use_kernel=use_kernel,
        interpret=interpret)
    h = h + y
    hn = rms_norm(h, params["norm_mlp"], cfg.norm_eps)
    if "moe" in params:
        y, aux = moe_mod.moe(params["moe"], hn, cfg,
                             top_k=knobs.topk_override, precision=prec,
                             ep_axis=ep_axis, mesh=mesh)
        h = h + y
    else:
        h = h + mlp_mod.mlp(params["mlp"], hn, precision=prec)
    return h, new_cache, aux


def block_decode(kind: str, params, h, position, cache, cfg: ModelConfig,
                 knobs: ApproxKnobs = PRECISE, *,
                 ep_axis: Optional[str] = None, mesh=None,
                 enc_out: Optional[jax.Array] = None, active=None,
                 use_kernel: Optional[bool] = None,
                 dyn_scatter: bool = False, interpret: bool = False):
    """Single-token decode. Returns (h, new_cache, aux).

    ``active`` (B,) bool masks per-slot cache writes (paged engines whose
    decode interleaves with background admission); None = all rows live.
    This is also the megastep scan body's per-row FREEZE contract
    (``lm.decode_megastep``): for a row with ``active=False``, every cache
    leaf the row owns must come back bit-identical — the paged write paths
    guarantee it by redirecting the row's scatter to the never-read null
    page (dyn_scatter / sharded kernel) or masking it out of the one-hot
    select, and ``mamba_decode`` by where-masking the state update. A
    row that dies mid-megastep (EOS / budget) therefore stops mutating
    its pages and SSM rows immediately, without a host round-trip.
    ``use_kernel`` forwards the paged-attention dispatch override;
    ``dyn_scatter`` selects the dynamic-index cache write for unsharded
    paged pools; under a ``mesh`` the paged path shard_maps the fused
    kernel when the pool layout allows (``attention.paged_decode_attention``)
    and ``interpret`` runs that kernel in Pallas interpret mode (CPU CI)."""
    aux = jnp.zeros((), jnp.float32)
    prec = knobs.matmul_precision
    if kind == MAMBA:
        y, new_cache = mamba_mod.mamba_decode(
            params["mixer"], rms_norm(h, params["norm"], cfg.norm_eps),
            cache, cfg, precision=prec, active=active)
        return h + y, new_cache, aux
    window = cfg.window if kind == LOCAL_ATTN else 0
    kv_scale = attn_mod.KV_SCALE if knobs.kv_quant else 0.0
    hn = rms_norm(h, params["norm_attn"], cfg.norm_eps)
    if isinstance(cache, attn_mod.PagedKVCache):
        y, new_cache = attn_mod.paged_decode_attention(
            params["attn"], hn, position, cache, cfg, window=window,
            kv_scale=kv_scale, active=active, use_kernel=use_kernel,
            dyn_scatter=dyn_scatter, mesh=mesh, interpret=interpret)
    else:
        y, new_cache = attn_mod.decode_attention(
            params["attn"], hn, position, cache, cfg, window=window,
            kv_scale=kv_scale)
    h = h + y
    if enc_out is not None:
        h = h + attn_mod.attention(
            params["cross"], rms_norm(h, params["norm_cross"], cfg.norm_eps),
            position[:, None], cfg, mode="cross", kv_x=enc_out)
    hn = rms_norm(h, params["norm_mlp"], cfg.norm_eps)
    if "moe" in params:
        y, aux = moe_mod.moe(params["moe"], hn, cfg,
                             top_k=knobs.topk_override, precision=prec,
                             ep_axis=ep_axis, mesh=mesh)
        h = h + y
    else:
        h = h + mlp_mod.mlp(params["mlp"], hn, precision=prec)
    return h, new_cache, aux
