"""Mamba2 (SSD) mixer: projections -> causal depthwise conv -> SSD scan ->
gated RMSNorm -> out_proj. Train/prefill uses the chunked SSD path (Pallas
kernel on TPU, chunked jnp elsewhere); decode is an O(1) single-token state
update — the reason SSM/hybrid archs run the ``long_500k`` cell at all.

Projections are stored as separate matrices (z / x / bc / dt) rather than one
fused ``in_proj`` so that TP sharding of the inner dim never slices across a
z|x|B|C boundary mid-shard (B/C are replicated — they broadcast over heads).
Parameter totals match the fused layout exactly.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, rms_norm
from repro.kernels import ops as kops


def _dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    return di, nh, s.d_state


def mamba_specs(cfg: ModelConfig):
    d = cfg.d_model
    di, nh, n = _dims(cfg)
    w = cfg.ssm.conv_width
    return {
        "in_z": ParamSpec((d, di), ("embed", "ssm_inner")),
        "in_x": ParamSpec((d, di), ("embed", "ssm_inner")),
        "in_bc": ParamSpec((d, 2 * n), ("embed", None)),
        "in_dt": ParamSpec((d, nh), ("embed", "ssm_heads")),
        "conv_x": ParamSpec((di, w), ("ssm_inner", None)),
        "conv_bc": ParamSpec((2 * n, w), (None, None)),
        "a_log": ParamSpec((nh,), ("ssm_heads",), init="ssm_a"),
        "d_skip": ParamSpec((nh,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((nh,), ("ssm_heads",), init="ssm_dt"),
        "gate_norm": ParamSpec((di,), ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed")),
    }


class MambaCache(NamedTuple):
    conv_x: jax.Array   # (B, W-1, di) trailing conv inputs
    conv_bc: jax.Array  # (B, W-1, 2N)
    state: jax.Array    # (B, nh, head_dim, d_state) fp32 SSD state


def init_mamba_cache(cfg: ModelConfig, batch: int,
                     dtype=jnp.bfloat16) -> MambaCache:
    di, nh, n = _dims(cfg)
    w = cfg.ssm.conv_width
    return MambaCache(
        conv_x=jnp.zeros((batch, w - 1, di), dtype),
        conv_bc=jnp.zeros((batch, w - 1, 2 * n), dtype),
        state=jnp.zeros((batch, nh, cfg.ssm.head_dim, n), jnp.float32))


def _causal_conv(u, conv_w, history=None):
    """Depthwise causal conv via shifted adds. u: (B,S,C); conv_w: (C,W)."""
    W = conv_w.shape[-1]
    B, S, C = u.shape
    if history is None:
        history = jnp.zeros((B, W - 1, C), u.dtype)
    padded = jnp.concatenate([history, u], axis=1)       # (B, S+W-1, C)
    out = jnp.zeros((B, S, C), jnp.float32)
    for j in range(W):
        out = out + padded[:, j:j + S].astype(jnp.float32) * conv_w[:, j]
    return jax.nn.silu(out).astype(u.dtype), padded[:, S:]


def mamba_mixer(params, x, cfg: ModelConfig, *, precision: str = "bf16"):
    """Full-sequence SSD mixer. x: (B,S,D) -> (B,S,D)."""
    B, S, D = x.shape
    di, nh, n = _dims(cfg)
    mm = kops.matmul(precision)
    z = mm(x, params["in_z"])
    xs, _ = _causal_conv(mm(x, params["in_x"]), params["conv_x"])
    bc, _ = _causal_conv(x @ params["in_bc"], params["conv_bc"])
    dt_raw = x @ params["in_dt"]
    b, c = jnp.split(bc, 2, axis=-1)
    xs4 = xs.reshape(B, S, nh, cfg.ssm.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    y = kops.ssd(xs4, dt, a, b, c, chunk=cfg.ssm.chunk,
                 d_skip=params["d_skip"])
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    return mm(y, params["out_proj"])


def mamba_prefill(params, x, cache: MambaCache, cfg: ModelConfig, *,
                  precision: str = "bf16"):
    """C-token prompt-chunk step continuing from an existing cache.

    x: (B,C,D) -> ((B,C,D), new_cache). Runs the chunked SSD path seeded with
    the cached state and conv history, so a prompt consumed chunk-by-chunk
    lands in exactly the state C successive ``mamba_decode`` calls produce —
    the serving chunked-prefill admission path.
    """
    from repro.kernels import ref as kref
    B, C, D = x.shape
    di, nh, n = _dims(cfg)
    mm = kops.matmul(precision)
    z = mm(x, params["in_z"])
    xs, hist_x = _causal_conv(mm(x, params["in_x"]), params["conv_x"],
                              history=cache.conv_x)
    bc, hist_bc = _causal_conv(x @ params["in_bc"], params["conv_bc"],
                               history=cache.conv_bc)
    dt_raw = x @ params["in_dt"]
    b, c = jnp.split(bc, 2, axis=-1)
    xs4 = xs.reshape(B, C, nh, cfg.ssm.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    # largest divisor of C <= the configured SSD chunk (C need not divide it)
    q = min(cfg.ssm.chunk, C)
    while C % q:
        q -= 1
    y, state = kref.ssd_chunked_ref(xs4, dt, a, b, c, chunk=q,
                                    d_skip=params["d_skip"],
                                    return_state=True, init_state=cache.state)
    y = y.reshape(B, C, di)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    return mm(y, params["out_proj"]), MambaCache(hist_x, hist_bc, state)


def mamba_decode(params, x, cache: MambaCache, cfg: ModelConfig, *,
                 precision: str = "bf16", active=None):
    """Single-token decode. x: (B,1,D) -> ((B,1,D), new_cache).

    ``active`` (B,) bool masks the state update per row: slots without a
    live request (e.g. while an admission prefills in the background) keep
    their conv history and SSD state bit-for-bit — a garbage decode token
    must never advance a row another path is building.
    """
    B, _, D = x.shape
    di, nh, n = _dims(cfg)
    mm = kops.matmul(precision)
    z = mm(x, params["in_z"])
    xs, hist_x = _causal_conv(mm(x, params["in_x"]), params["conv_x"],
                              history=cache.conv_x)
    bc, hist_bc = _causal_conv(x @ params["in_bc"], params["conv_bc"],
                               history=cache.conv_bc)
    dt_raw = x @ params["in_dt"]
    b, c = jnp.split(bc[:, 0], 2, axis=-1)               # (B, N)
    xs3 = xs[:, 0].reshape(B, nh, cfg.ssm.head_dim).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))   # (B,nh)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)
    bf = b.astype(jnp.float32)
    state = (cache.state * da[..., None, None]
             + (dt[..., None] * xs3)[..., None] * bf[:, None, None, :])
    y = jnp.einsum("bhpn,bn->bhp", state, c.astype(jnp.float32))
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xs3
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    new_cache = MambaCache(hist_x, hist_bc, state)
    if active is not None:
        keep = lambda new, old: jnp.where(
            active.reshape((B,) + (1,) * (old.ndim - 1)), new, old)
        new_cache = MambaCache(*(keep(n, o)
                                 for n, o in zip(new_cache, cache)))
    return mm(y, params["out_proj"]), new_cache
