"""Top-k MoE layer with sort-based dispatch and expert parallelism.

Dispatch is index-based (argsort by expert, capacity-bounded slots) — never a
one-hot dispatch tensor — so activation inflation is exactly tokens x top_k.
Distributed mode runs under a FULLY-MANUAL ``shard_map`` (every mesh axis):
tokens are flat-sharded over (pod, data, model), experts are sharded over
``model``, and two ``all_to_all``s move capacity slots to/from expert owners.
Under the fsdp_tp policy the expert weights' embed dim is FSDP-sharded over
``data`` and all-gathered on entry (hand-written — partial-manual shard_map
transposes of all_to_all crash XLA CPU, see EXPERIMENTS.md §Dry-run).

Pliant knob: ``top_k`` override (expert perforation) — routing to fewer
experts cuts active FLOPs and all-to-all bytes at bounded quality loss.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec
from repro.kernels import ops as kops
from repro.dist import annotate


def moe_specs(cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    return {
        # gate stays replicated (tiny): routing must see full d
        "wg": ParamSpec((d, e), (None, None)),
        "wi_gate": ParamSpec((e, d, f), ("expert", "embed", "mlp")),
        "wi_up": ParamSpec((e, d, f), ("expert", "embed", "mlp")),
        "wo": ParamSpec((e, f, d), ("expert", "mlp", "embed")),
    }


def _capacity(n_tokens: int, top_k: int, n_experts: int, cf: float,
              align: int = 8) -> int:
    c = int(cf * n_tokens * top_k / n_experts)
    return max(align, -(-c // align) * align)


def _route(x2, wg, top_k: int, capacity: int, n_experts: int):
    """x2: (T, D). Returns (slots (T,k), weights (T,k), keep (T,k), aux)."""
    logits = (x2 @ wg).astype(jnp.float32)                  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, top_k)                 # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    flat_e = ids.reshape(-1)                                # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=n_experts)
    seg_start = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(flat_e.shape[0]) - seg_start[sorted_e]
    keep_sorted = rank < capacity
    slot_sorted = sorted_e * capacity + jnp.minimum(rank, capacity - 1)
    slot = jnp.zeros_like(flat_e).at[order].set(slot_sorted)
    keep = jnp.zeros(flat_e.shape, bool).at[order].set(keep_sorted)
    me = jnp.mean(probs, axis=0)
    ce = counts.astype(jnp.float32) / flat_e.shape[0]
    aux = n_experts * jnp.sum(me * ce)
    return (slot.reshape(-1, top_k), gate.astype(x2.dtype),
            keep.reshape(-1, top_k), aux)


def _expert_ffn(xe, wi_gate, wi_up, wo, precision: str):
    """xe: (E_loc, C', D); weights (E_loc, D, F) / (E_loc, F, D)."""
    if precision == "int8":
        def one(x, wg_, wu_, wo_):
            g = jax.nn.silu(kops.quantized_matmul(x, wg_).astype(jnp.float32))
            u = kops.quantized_matmul(x, wu_)
            return kops.quantized_matmul(g.astype(x.dtype) * u, wo_)
        return jax.vmap(one)(xe, wi_gate, wi_up, wo)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wi_gate,
                               preferred_element_type=jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", xe, wi_up)
    return jnp.einsum("ecf,efd->ecd", g.astype(xe.dtype) * u, wo)


def _moe_local(params, x2, cfg: ModelConfig, top_k: int, precision: str,
               ep_axis: Optional[str]):
    """Core MoE on local tokens x2: (T, D). Inside shard_map when ``ep_axis``
    is set (experts sharded over that axis), else single-device."""
    E = cfg.moe.n_experts
    T = x2.shape[0]
    C = _capacity(T, top_k, E, cfg.moe.capacity_factor)
    slot, gate, keep, aux = _route(x2, params["wg"], top_k, C, E)
    flat_slot = slot.reshape(-1)
    flat_keep = keep.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(T), top_k)
    buf = jnp.zeros((E * C, x2.shape[1]), x2.dtype)
    buf = buf.at[flat_slot].add(
        jnp.where(flat_keep[:, None], x2[tok_idx], 0))
    if ep_axis is not None:
        xe = buf.reshape(E, C, -1)
        xe = jax.lax.all_to_all(xe, ep_axis, split_axis=0, concat_axis=1,
                                tiled=True)
        ye = _expert_ffn(xe, params["wi_gate"], params["wi_up"], params["wo"],
                         precision)
        ye = jax.lax.all_to_all(ye, ep_axis, split_axis=1, concat_axis=0,
                                tiled=True)
        buf_out = ye.reshape(E * C, -1)
    else:
        xe = buf.reshape(E, C, -1)
        ye = _expert_ffn(xe, params["wi_gate"], params["wi_up"], params["wo"],
                         precision)
        buf_out = ye.reshape(E * C, -1)
    y = buf_out[flat_slot].reshape(T, top_k, -1)
    y = jnp.sum(y * (gate * keep)[..., None], axis=1)
    return y.astype(x2.dtype), aux


def moe(params, x, cfg: ModelConfig, *, top_k: int = 0,
        precision: str = "bf16", ep_axis: Optional[str] = None,
        mesh=None):
    """x: (B, S, D) -> (y, aux_loss). ``ep_axis``: mesh axis for EP."""
    B, S, D = x.shape
    top_k = top_k or cfg.moe.top_k
    if ep_axis is None or mesh is None:
        y, aux = _moe_local(params, x.reshape(-1, D), cfg, top_k, precision,
                            None)
        return y.reshape(B, S, D), aux

    from jax.sharding import PartitionSpec as P
    T = B * S
    all_axes = tuple(mesh.shape.keys())
    n_all = int(np.prod(list(mesh.shape.values())))
    if T % n_all == 0:
        tok_axes = all_axes                     # flat tokens over every axis
    elif T % mesh.shape[ep_axis] == 0:
        tok_axes = (ep_axis,)                   # decode-size batches
    else:
        y, aux = _moe_local(params, x.reshape(-1, D), cfg, top_k, precision,
                            None)
        return y.reshape(B, S, D), aux          # tiny batch: replicated
    fsdp = annotate.FSDP_AXIS
    fsdp = fsdp if (fsdp in mesh.shape and
                    cfg.d_model % mesh.shape.get(fsdp, 1) == 0) else None

    def body(params_loc, x_loc):
        p = dict(params_loc)
        if fsdp is not None:                    # hand-written FSDP unshard
            p["wi_gate"] = jax.lax.all_gather(p["wi_gate"], fsdp, axis=1,
                                              tiled=True)
            p["wi_up"] = jax.lax.all_gather(p["wi_up"], fsdp, axis=1,
                                            tiled=True)
            p["wo"] = jax.lax.all_gather(p["wo"], fsdp, axis=2, tiled=True)
        y, aux = _moe_local(p, x_loc, cfg, top_k, precision, ep_axis)
        for ax in tok_axes:
            aux = jax.lax.pmean(aux, ax)
        return y, aux

    pspec = {
        "wg": P(),
        "wi_gate": P(ep_axis, fsdp, None),
        "wi_up": P(ep_axis, fsdp, None),
        "wo": P(ep_axis, None, fsdp),
    }
    y2, aux = jax.shard_map(
        body, mesh=mesh,
        in_specs=(pspec, P(tok_axes, None)),
        out_specs=(P(tok_axes, None), P()),
        axis_names=set(all_axes), check_vma=False)(params, x.reshape(-1, D))
    return y2.reshape(B, S, D), aux
