"""Three-term roofline from compiled dry-run artifacts (TPU v5e targets).

    compute term    = HLO_FLOPs / peak_FLOPs            (per-chip program)
    memory term     = HLO_bytes / HBM_bw
    collective term = wire_bytes / link_bw

``cost_analysis``/``memory_analysis`` describe the *per-device* SPMD program,
so no division by chip count is applied. Collective bytes are parsed from the
optimized HLO text with ring-model wire coefficients per op kind.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict

# TPU v5e-class hardware constants (per chip), per the brief.
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
# ring-model wire bytes per device, as multiple of the parsed payload bytes
_WIRE_COEF = {
    "all-gather": 1.0,        # receives the full result
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "ragged-all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(type_str: str) -> int:
    m = _SHAPE_RE.match(type_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes by collective kind, from optimized HLO text.

    For each collective instruction we take the larger of (result bytes,
    summed operand bytes) as the payload — correct for both gather-like
    (result larger) and scatter-like (operands larger) ops — then apply the
    ring coefficient.
    """
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", line)
        if not m:
            continue
        result_type, opname = m.groups()
        kind = None
        for c in _COLLECTIVES:
            if opname == c or opname.startswith(c + "."):
                kind = c
                break
        if kind is None:
            continue
        if kind == "all-to-all" and "ragged-all-to-all" in line:
            kind = "ragged-all-to-all"
        # result bytes (may be a tuple type)
        res_bytes = sum(_shape_bytes(t) for t in
                        re.findall(r"\w+\[[\d,]*\]", result_type))
        # operand bytes: parse typed operands inside the call parens
        paren = line[line.find("(", line.find(opname)):]
        op_bytes = sum(_shape_bytes(t) for t in
                       re.findall(r"\w+\[[\d,]*\]", paren))
        payload = max(res_bytes, op_bytes)
        # XLA *CPU* promotes bf16 all-reduces to f32 (AllReducePromotion:
        # `to_apply=%...promoted`); TPU reduces bf16 natively. Count the
        # wire at the pre-promotion dtype so the target-hardware roofline
        # is not inflated 2x by a host-backend artifact.
        if kind == "all-reduce" and "promoted" in line:
            payload *= 0.5
        out[kind] = out.get(kind, 0.0) + _WIRE_COEF[kind] * payload
    return out


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_chip: float
    hlo_flops: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops_per_chip / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute-time / bound-time: the score."""
        useful_s = self.model_flops_per_chip / PEAK_FLOPS
        return useful_s / max(self.bound_s, 1e-30)


def terms_from_artifact(art: dict, model_flops_total: float,
                        n_chips: int) -> RooflineTerms:
    wire = sum(art.get("collectives", {}).values())
    return RooflineTerms(
        compute_s=art["flops"] / PEAK_FLOPS,
        memory_s=art["bytes_accessed"] / HBM_BW,
        collective_s=wire / ICI_BW,
        model_flops_per_chip=model_flops_total / n_chips,
        hlo_flops=art["flops"],
    )


# ------------------------------------------------ analytic model FLOPs ----

def model_flops(cfg, shape, knobs=None) -> float:
    """Analytic useful FLOPs for one step of a cell (whole cluster).

    Train: 6·N_active·tokens + 3·attention; prefill: 2·N_active·tokens +
    attention; decode: 2·N_active·B + decode attention reads.
    """
    from repro.configs.base import ATTN, LOCAL_ATTN, MAMBA, SHARED_ATTN
    from repro.approx.knobs import PRECISE, keep_groups
    knobs = knobs or PRECISE
    n_total = cfg.param_count()
    # active params: MoE uses top_k of n_experts expert MLPs
    n_active = n_total
    if cfg.moe is not None:
        k = knobs.topk_override or cfg.moe.top_k
        expert_p = cfg.moe.n_experts * 3 * cfg.d_model * cfg.d_ff
        active_expert_p = k * 3 * cfg.d_model * cfg.d_ff
        n_active = n_total - cfg.n_layers * (expert_p - active_expert_p)
    # embedding gather is not a matmul; unembed matmul counted separately
    n_active -= cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    keep = keep_groups(cfg.n_groups, knobs.layer_skip)
    layer_frac = len(keep) / cfg.n_groups

    B = shape.global_batch
    if knobs.token_drop and shape.kind == "train":
        B = max(1, int(B * (1.0 - knobs.token_drop)))
    S = shape.seq_len
    if shape.kind == "decode":
        tokens = B
        kv_len = S
    else:
        tokens = B * S
        kv_len = S / 2.0            # causal average

    # attention einsum flops per token: 4 * kv * q_dim per attn layer
    attn = 0.0
    for kind in cfg.kinds():
        if kind in (ATTN, SHARED_ATTN):
            kv = kv_len
        elif kind == LOCAL_ATTN:
            kv = min(cfg.window, kv_len) if shape.kind == "decode" \
                else min(cfg.window, S) / 2.0 + cfg.window / 2.0
            kv = min(kv, kv_len)
        else:
            continue
        if knobs.kv_keep_stride > 1 and shape.kind != "decode":
            kv = kv / knobs.kv_keep_stride
        attn += 4.0 * kv * cfg.q_dim
    attn *= tokens * layer_frac
    if cfg.family == "encdec" and shape.kind != "decode":
        # encoder self-attn + decoder cross-attn
        attn += (cfg.n_encoder_layers * 4.0 * cfg.encoder_seq * cfg.q_dim
                 * B * cfg.encoder_seq)
        attn += cfg.n_layers * 4.0 * cfg.encoder_seq * cfg.q_dim * tokens

    # ssd flops per token per mamba layer: intra-chunk ~2*Q*di + state 4*di*N
    ssd = 0.0
    if cfg.ssm is not None:
        di = cfg.ssm.expand * cfg.d_model
        q = cfg.ssm.chunk if shape.kind != "decode" else 1
        per_tok = 2.0 * q * di + 6.0 * di * cfg.ssm.d_state
        n_mamba = sum(1 for k in cfg.kinds() if k == MAMBA)
        ssd = per_tok * n_mamba * tokens * layer_frac

    matmul = 2.0 * n_active * tokens * layer_frac \
        + 2.0 * cfg.vocab_size * cfg.d_model * tokens  # unembed/logits
    if shape.kind == "decode":
        fwd = matmul + attn + ssd
        return fwd
    if shape.kind == "prefill":
        return matmul + attn + ssd
    return 3.0 * (matmul + attn + ssd)      # fwd + 2x bwd


def admission_terms(cfg, chunk_len: int, kv_len: int, *, n_shards: int = 1,
                    kv_quant: bool = False):
    """Per-DEVICE roofline terms of ONE admission chunk's attention.

    Sums ``kernels.ring_attention``'s per-device cost model over the
    config's attention layers (local layers clamp the visible context to
    their window) and prices it against the chip constants. ``n_shards`` is
    the ring plan's shard count (1 = unsharded): the admission compute/HBM
    terms divide by it, which is exactly what the arbiter's pressure
    attribution for the admission axis should see on a mesh. Returns a dict
    with ``flops_per_device`` / ``hbm_bytes_per_device`` / ``compute_s`` /
    ``memory_s``."""
    from repro.configs.base import ATTN, LOCAL_ATTN, SHARED_ATTN
    from repro.kernels.ring_attention import (sharded_prefill_attn_flops,
                                              sharded_prefill_hbm_bytes)
    hd = cfg.resolved_head_dim
    kv_bytes = 1 if kv_quant else 4
    flops = bytes_ = 0.0
    for kind in cfg.kinds():
        if kind in (ATTN, SHARED_ATTN):
            kv = kv_len
        elif kind == LOCAL_ATTN:
            kv = min(cfg.window + chunk_len, kv_len)
        else:
            continue
        flops += sharded_prefill_attn_flops(chunk_len, kv, cfg.n_heads, hd,
                                            n_shards=n_shards)
        bytes_ += sharded_prefill_hbm_bytes(chunk_len, kv, cfg.n_kv_heads,
                                            hd, n_shards=n_shards,
                                            n_heads=cfg.n_heads,
                                            kv_bytes=kv_bytes)
    return {"flops_per_device": flops, "hbm_bytes_per_device": bytes_,
            "compute_s": flops / PEAK_FLOPS, "memory_s": bytes_ / HBM_BW}


def decode_min_bytes(cfg, shape, n_chips: int, kv_quant: bool = False):
    """Kernel-adjusted lower bound on per-chip decode memory traffic: weights
    + KV/SSM state read once per token step (what the fused Pallas
    flash-decode path achieves on TPU — the HLO term additionally counts the
    softmax-chain traffic that stays in VMEM on hardware)."""
    from repro.configs.base import ATTN, LOCAL_ATTN, MAMBA, SHARED_ATTN
    params_b = cfg.param_count() * 2.0
    kv_bytes = 1 if kv_quant else 2
    cache_b = 0.0
    for kind in cfg.kinds():
        if kind in (ATTN, SHARED_ATTN):
            cache_b += 2 * cfg.kv_dim * kv_bytes * shape.seq_len
        elif kind == LOCAL_ATTN:
            cache_b += 2 * cfg.kv_dim * kv_bytes * min(cfg.window,
                                                       shape.seq_len)
        elif kind == MAMBA and cfg.ssm is not None:
            di = cfg.ssm.expand * cfg.d_model
            nh = di // cfg.ssm.head_dim
            cache_b += nh * cfg.ssm.head_dim * cfg.ssm.d_state * 4
    cache_b *= shape.global_batch
    return (params_b + cache_b) / n_chips
