"""Sharded checkpointing with async write, elastic restore, and preemption
hooks — the fault-tolerance substrate.

Layout: one ``.npz`` per logical shard-group plus a JSON manifest recording
step, mesh shape, and the flattened tree structure. ``restore`` re-shards
onto ANY mesh (elastic scaling: restore a 256-chip checkpoint onto 128 or 512
chips) because arrays are saved unsharded-logical and re-``device_put`` with
the new shardings.

Scalability note (DESIGN.md): on a real multi-host pod each host writes only
its addressable shards; this container is single-host so the gather is a
no-op. The manifest/restore protocol is host-count independent.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import signal
import sys
import tempfile
import threading
import time
import zipfile
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

# everything a truncated/partial checkpoint (simulated kill mid-write, torn
# copy) can raise on load: bad manifest JSON, torn npz central directory,
# missing arrays, shape/leaf-count drift, vanished files
CORRUPT_ERRORS = (json.JSONDecodeError, zipfile.BadZipFile, KeyError,
                  AssertionError, ValueError, EOFError, OSError)


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _mesh_of(leaves) -> Optional[Dict]:
    """Source mesh metadata (shape + axis names) if the tree is sharded.

    Restore never *requires* it — arrays are saved unsharded-logical and
    re-``device_put`` with the target shardings — but the manifest records the
    save-side topology so elastic (2,4)->(4,2)/(1,8) restores are auditable.
    """
    for x in leaves:
        sh = getattr(x, "sharding", None)
        mesh = getattr(sh, "mesh", None)
        if mesh is not None and getattr(mesh, "shape", None):
            return {"shape": {str(k): int(v) for k, v in mesh.shape.items()}}
    return None


def save(path: str, tree, step: int, *, extra: Optional[Dict] = None) -> None:
    """Atomic (write-then-rename) checkpoint save."""
    path = pathlib.Path(path)
    tmp = pathlib.Path(tempfile.mkdtemp(dir=path.parent if path.parent.exists()
                                        else None, prefix=".ckpt_tmp_"))
    leaves, treedef = _flatten(tree)
    arrays = {f"a{i}": np.asarray(jax.device_get(x)) for i, x in
              enumerate(leaves)}
    np.savez(tmp / "shard0.npz", **arrays)
    manifest = {
        "step": int(step),
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "dtypes": [str(x.dtype) for x in arrays.values()],
        "shapes": [list(x.shape) for x in arrays.values()],
        "mesh": _mesh_of(leaves),
        "time": time.time(),
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if path.exists():
        shutil.rmtree(path)
    os.replace(tmp, path)


def restore(path: str, like_tree, *, shardings=None):
    """Restore into the structure of ``like_tree``; optionally re-shard.

    ``like_tree`` may contain ShapeDtypeStructs (abstract restore target).
    Returns (tree, step).
    """
    path = pathlib.Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "shard0.npz")
    leaves, treedef = _flatten(like_tree)
    assert len(leaves) == manifest["n_leaves"], "tree structure changed"
    out = []
    sh_leaves = jax.tree.leaves(shardings) if shardings is not None else \
        [None] * len(leaves)
    for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = data[f"a{i}"]
        assert tuple(arr.shape) == tuple(ref.shape), \
            (i, arr.shape, ref.shape)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), manifest["step"]


def latest_step(root: str) -> Optional[int]:
    root = pathlib.Path(root)
    if not root.exists():
        return None
    steps = [int(p.name.split("_")[-1]) for p in root.glob("step_*")
             if (p / "manifest.json").exists()]
    return max(steps) if steps else None


def all_steps(root: str) -> List[int]:
    """Every step directory present (descending), manifest or not — the
    corruption-tolerant restore scans these newest-first."""
    root = pathlib.Path(root)
    if not root.exists():
        return []
    steps = []
    for p in root.glob("step_*"):
        try:
            steps.append(int(p.name.split("_")[-1]))
        except ValueError:
            continue
    return sorted(steps, reverse=True)


class CheckpointManager:
    """Periodic + async checkpointing with retention and preemption hook.

    ``save_async`` snapshots to host memory synchronously (cheap device_get)
    and writes to disk on a background thread — the train loop never blocks
    on storage. SIGTERM (preemption) triggers a final synchronous save.
    """

    def __init__(self, root: str, *, period: int = 100, keep: int = 3,
                 install_sigterm: bool = False):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.period = period
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._last_tree = None
        self._last_step = None
        self.skipped: List[str] = []    # corrupt checkpoints skipped on
        # restore (audit trail for the loud warning)
        # a kill mid-``save`` leaves the stage dir behind (the rename never
        # ran, so the checkpoint set itself is intact) — sweep stale stages
        for tmp in self.root.glob(".ckpt_tmp_*"):
            shutil.rmtree(tmp, ignore_errors=True)
        if install_sigterm:
            signal.signal(signal.SIGTERM, self._on_sigterm)

    def _on_sigterm(self, signum, frame):   # pragma: no cover - signal path
        if self._last_tree is not None:
            self.save_sync(self._last_tree, self._last_step)
        raise SystemExit(143)

    def maybe_save(self, tree, step: int) -> bool:
        self._last_tree, self._last_step = tree, step
        if step % self.period != 0:
            return False
        self.save_async(tree, step)
        return True

    def save_async(self, tree, step: int) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(host_tree, step), daemon=True)
        self._thread.start()

    def save_sync(self, tree, step: int) -> None:
        self.wait()
        self._write(tree, step)

    def _write(self, tree, step: int) -> None:
        save(self.root / f"step_{step}", tree, step)
        self._gc()

    def _gc(self) -> None:
        steps = sorted(int(p.name.split("_")[-1])
                       for p in self.root.glob("step_*"))
        for s in steps[: -self.keep]:
            shutil.rmtree(self.root / f"step_{s}", ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def restore_latest(self, like_tree, *, shardings=None):
        """Restore the newest LOADABLE checkpoint, scanning steps newest-
        first and SKIPPING — with a loud warning, never a crash — any that a
        simulated kill or torn copy left truncated/partial (bad manifest
        JSON, torn npz, missing arrays, shape/leaf drift). A fleet restart
        must come back from the best intact state it has, not die on the
        worst; skipped paths are recorded on ``self.skipped``."""
        for step in all_steps(self.root):
            path = self.root / f"step_{step}"
            try:
                return restore(path, like_tree, shardings=shardings)
            except CORRUPT_ERRORS as e:
                self.skipped.append(str(path))
                print(f"WARNING: skipping corrupt/partial checkpoint {path} "
                      f"({type(e).__name__}: {e}) — falling back to an "
                      "older step", file=sys.stderr)
        return None, None
