"""Lightweight QoS performance monitor (paper §4.1).

Client-side end-to-end latency sampler with an *adaptive sampling rate*: when
observed tail latency approaches the QoS target, the sample rate rises toward
1.0; far from the boundary it decays, keeping overhead negligible — mirroring
the paper's "adaptive sampling of end-to-end latency".
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Deque, Optional

import numpy as np


@dataclass
class LatencyMonitor:
    qos_target_s: float
    window: int = 4096
    min_rate: float = 0.05
    min_samples: int = 20           # below this the tail estimate abstains
    _buf: Deque[float] = field(default_factory=lambda: collections.deque())
    _rate: float = 1.0
    _rng: np.random.Generator = field(
        default_factory=lambda: np.random.default_rng(0))
    n_seen: int = 0
    n_recorded: int = 0

    def record(self, latency_s: float) -> None:
        self.n_seen += 1
        # bootstrap: below min_samples the estimator abstains entirely, so
        # thinning there starves the controller of any tail signal (it would
        # hold forever once the adaptive rate decays); fill first, thin after
        if len(self._buf) >= self.min_samples \
                and self._rng.random() > self._rate:
            return
        self.n_recorded += 1
        self._buf.append(float(latency_s))
        while len(self._buf) > self.window:
            self._buf.popleft()
        if self.n_recorded % 64 == 0:
            self._adapt()

    def _adapt(self) -> None:
        p = self.p99()
        if p is None:
            return
        closeness = p / self.qos_target_s          # >= 1: violating
        if closeness > 0.8:
            self._rate = 1.0
        else:
            self._rate = max(self.min_rate, closeness)

    def record_many(self, latencies) -> None:
        """Vectorized record (thinned by the current sample rate; the first
        samples up to ``min_samples`` always land — see ``record``)."""
        import numpy as _np
        lat = _np.asarray(latencies, float)
        self.n_seen += lat.size
        need = max(0, self.min_samples - len(self._buf))
        head, tail = lat[:need], lat[need:]
        if self._rate < 1.0:
            tail = tail[self._rng.random(tail.size) <= self._rate]
        lat = _np.concatenate([head, tail])
        self.n_recorded += lat.size
        self._buf.extend(lat.tolist())
        while len(self._buf) > self.window:
            self._buf.popleft()
        self._adapt()

    def record_megastep(self, wall_s: float, tokens_per_row) -> None:
        """Attribute one megastep's wall time to per-token samples: a fused
        K-step dispatch surfaces ONE host stamp for up to K tokens per row,
        so each row that emitted ``n > 0`` tokens contributes ``n`` samples
        of ``wall_s / n`` — total mass per row equals the wall time the
        client actually experienced, and the estimator keeps seeing
        per-token latencies comparable with the per-step engine's."""
        lat = []
        for n in tokens_per_row:
            n = int(n)
            if n > 0:
                lat.extend([wall_s / n] * n)
        if lat:
            self.record_many(lat)

    def p99(self) -> Optional[float]:
        if len(self._buf) < self.min_samples:
            return None
        return float(np.percentile(np.asarray(self._buf), 99))

    def mean(self) -> Optional[float]:
        if not self._buf:
            return None
        return float(np.mean(np.asarray(self._buf)))

    def qos_violated(self) -> bool:
        p = self.p99()
        return p is not None and p > self.qos_target_s

    def slack(self) -> float:
        """(target - p99) / target; negative when violating."""
        p = self.p99()
        if p is None:
            return 0.0
        return (self.qos_target_s - p) / self.qos_target_s

    def reset_window(self) -> None:
        self._buf.clear()

    def consume_window(self):
        """One decision boundary: read the closing window's ``(p99,
        violated, slack)`` and reset so the next decision acts on fresh
        data. This is THE reset-window convention — ``PliantRuntime.
        maybe_decide`` and ``colocation.simulate`` both consume through
        here instead of each hand-rolling read-then-reset."""
        p = self.p99()
        violated = p is not None and p > self.qos_target_s
        slack = 0.0 if p is None \
            else (self.qos_target_s - p) / self.qos_target_s
        self.reset_window()
        return p, violated, slack

    @property
    def sample_rate(self) -> float:
        return self._rate
