"""Tenant protocol: one co-scheduled approximate application under the
multi-tenant Pliant control plane.

The arbiter (``core/arbiter.py``) is deliberately agnostic to WHAT a tenant
is — a batch training job yielding chip-groups, a paged serving engine
yielding pool pages, or a queueing-model job inside the colocation
simulator. Every tenant exposes the same small surface:

* ``n_variants`` / ``set_variant(i)`` — the AOT-compiled approximation
  ladder (index 0 = precise) and the actuator that hot-swaps it at the next
  step boundary.
* ``reclaim(k)`` / ``return_quanta(k)`` — shrink/regrow the tenant's share
  of the contended resource in quanta (chip-groups, pool pages). Each
  tenant carries its OWN budget (``max_reclaim``) — heterogeneous tenants
  no longer share one budget sized from the first job.
* ``pressure(t, variant)`` — the per-resource ``ResourcePressure`` the
  tenant exerts on the shared substrate, sourced from the explorer's
  compiled-cell ``cost_analysis`` roofline terms per variant (that is what
  ``VariantTable`` pressures are), scaled by whatever share of the resource
  the tenant currently holds. This is what lets the interference-aware
  arbiter attribute contention and pick the victim that relieves the most
  of it per unit quality loss.

Concrete adapters:

* ``TrainTenant``   — elastic train job: executable swap via the table,
  chip-group reshard via ``reshard_fn(reclaimed)``.
* ``ServeTenant``   — paged ``ServeEngine``: deferred-safe variant hot-swap
  (``engine.request_variant``), ``PagePool`` quanta via ``set_reclaimed``;
  HBM pressure scales with live-page occupancy.
* ``SimTenant``     — the colocation simulator's ``BatchJob`` (state lives
  on the job so ``advance``/``interference_of`` see actuations directly).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.core.variants import ResourcePressure, VariantTable


class Tenant:
    """Protocol + shared bookkeeping for one arbitrated application.

    Subclasses implement ``_on_variant``/``_on_reclaimed`` actuation hooks
    (and may override ``pressure`` for tenant-specific scaling). State is
    kept here so the arbiter can read it back uniformly."""

    name: str = "tenant"
    table: Optional[VariantTable] = None
    max_reclaim: int = 0          # per-tenant reclaimable-quanta budget
    n_quanta: int = 1             # total quanta backing the tenant (relief
    _variant: int = 0             # per reclaimed quantum ~ pressure/n_quanta)
    _reclaimed: int = 0
    reshard_fn: Optional[Callable[[int], None]] = None   # late-bound quanta
    # actuator (``rebind``): receives the ABSOLUTE reclaimed count, and is
    # honored by EVERY adapter's ``_on_reclaimed`` chain — a runtime's
    # ``attach_reclaimer`` must never silently no-op on a bound tenant

    # ------------------------------------------------------------ variants --

    @property
    def n_variants(self) -> int:
        return len(self.table) if self.table is not None else 1

    @property
    def variant(self) -> int:
        return self._variant

    def set_variant(self, idx: int) -> None:
        assert 0 <= idx < self.n_variants, (idx, self.n_variants)
        self._variant = idx
        self._on_variant(idx)

    def quality_loss(self, variant: Optional[int] = None) -> float:
        v = self.variant if variant is None else variant
        return self.table.variants[v].quality_loss if self.table else 0.0

    # -------------------------------------------------------------- quanta --

    @property
    def reclaimed(self) -> int:
        return self._reclaimed

    def reclaim(self, k: int = 1) -> None:
        self._reclaimed = min(self._reclaimed + k, self.max_reclaim)
        self._on_reclaimed(self._reclaimed)

    def return_quanta(self, k: int = 1) -> None:
        self._reclaimed = max(self._reclaimed - k, 0)
        self._on_reclaimed(self._reclaimed)

    # ------------------------------------------------------------ pressure --

    def share(self) -> float:
        """Fraction of the tenant's nominal resource share still held."""
        return max(self.n_quanta - self.reclaimed, 0) / max(self.n_quanta, 1)

    def pressure(self, t: float = 0.0,
                 variant: Optional[int] = None) -> ResourcePressure:
        """Pressure the tenant exerts NOW (or would exert at ``variant``):
        the explorer's roofline terms for that variant, scaled by the share
        of the resource the tenant currently holds."""
        v = self.variant if variant is None else variant
        base = self.table.variants[v].pressure if self.table \
            else ResourcePressure()
        return base.scaled(self.share())

    # ----------------------------------------------------- actuation hooks --

    def rebind(self, fn: Callable[[int], None],
               max_reclaim: Optional[int] = None) -> None:
        """Late-bind the quanta actuator (construction order often puts the
        actuator after the runtime) and optionally restore the budget."""
        self.reshard_fn = fn
        if max_reclaim is not None:
            self.max_reclaim = max_reclaim
            self.n_quanta = max(self.n_quanta, max_reclaim + 1)

    def _on_variant(self, idx: int) -> None:
        pass

    def _on_reclaimed(self, total: int) -> None:
        if self.reshard_fn is not None:
            self.reshard_fn(total)

    # ------------------------------------------------------------ capacity --

    def on_capacity(self, ev) -> None:
        """Receive a ``dist.elastic.CapacityEvent`` fanned out by
        ``PliantRuntime.inject`` (which has ALREADY recorded it as
        contention pressure). Adapters with an elastic substrate actuate:
        the serve adapter re-homes its engine, the train adapter reshards
        its params/optimizer mid-flight. The base tenant has nothing to
        shrink — pressure alone (variant ladder via the arbiter) is its
        whole response."""


@dataclass
class TrainTenant(Tenant):
    """Elastic batch-training job: the table's jitted step executables are
    hot-swapped by index (``runtime.step_executable``); ``reshard_fn`` — when
    the job is elastic — receives the ABSOLUTE reclaimed chip-group count
    (the PR-1 ``dist`` reshard/restore path, or a scheduler callback)."""
    table: VariantTable = None
    name: str = "train"
    reshard_fn: Optional[Callable[[int], None]] = None
    max_reclaim: int = 0
    n_quanta: int = 1
    # live-shrink actuator: receives each CapacityEvent fanned out by
    # ``PliantRuntime.inject``; the launch/train chaos path binds it to the
    # mid-flight ``dist.elastic.reshard_live`` of (params, optimizer state)
    # on the surviving mesh + a variant-table recompile
    elastic_fn: Optional[Callable[[Any], None]] = None
    _variant: int = field(default=0, init=False)
    _reclaimed: int = field(default=0, init=False)

    def __post_init__(self):
        if self.reshard_fn is None:
            # no actuator for quanta reclamation: a non-zero budget would
            # burn decision intervals on phantom RECLAIM/RETURN actions
            # before the arbiter steps the tenant back toward precise
            self.max_reclaim = 0
        self.n_quanta = max(self.n_quanta, self.max_reclaim + 1)

    def on_capacity(self, ev) -> None:
        if self.elastic_fn is not None:
            self.elastic_fn(ev)


@dataclass
class ServeTenant(Tenant):
    """Paged ``ServeEngine`` adapter. Variant swaps go through
    ``engine.request_variant`` (applied at the next SAFE step boundary — a
    mid-admission swap would mix prefill executables within one request);
    quanta are ``PagePool`` pages via ``set_reclaimed``. Dense engines have
    no reclaimable pool, so their budget is 0 (variant knob only)."""
    engine: Any = None
    name: str = "serve"
    table: VariantTable = field(init=False)
    max_reclaim: int = field(init=False)
    n_quanta: int = field(init=False)
    _variant: int = field(default=0, init=False)
    _reclaimed: int = field(default=0, init=False)

    def __post_init__(self):
        self.table = self.engine.table
        pool = getattr(self.engine, "pool", None)
        self.max_reclaim = pool.max_quanta if pool is not None else 0
        self.n_quanta = (max(pool.spec.usable // max(pool.quantum, 1), 1)
                         if pool is not None else 1)
        self._variant = self.engine.active_variant

    @property
    def variant(self) -> int:
        # decision-state view: the engine may still be deferring the swap
        return self._variant

    def _on_variant(self, idx: int) -> None:
        self.engine.request_variant(idx)

    def _on_reclaimed(self, total: int) -> None:
        if self.engine.pool is not None:
            self.engine.pool.set_reclaimed(total)
        super()._on_reclaimed(total)     # honor a late-bound actuator too

    def on_capacity(self, ev) -> None:
        # runtime already recorded the pressure (inject fans out AFTER
        # notify_capacity) — route actuation only, no double count
        self.engine.inject(ev, notify_runtime=False)

    def pressure(self, t: float = 0.0,
                 variant: Optional[int] = None) -> ResourcePressure:
        """Roofline pressure of the (target) serving variant; for paged
        engines the HBM term scales with live-page occupancy — the fused
        decode kernel streams mapped pages, not ``slots x max_len`` rings
        (DESIGN.md §10), so a half-empty pool exerts half the KV traffic."""
        v = self.variant if variant is None else variant
        p = self.table.variants[v].pressure
        pool = self.engine.pool
        if pool is not None:
            p = ResourcePressure(hbm=p.hbm * max(pool.occupancy(), 0.05),
                                 ici=p.ici, flops=p.flops)
        return p


@dataclass
class SimTenant(Tenant):
    """Colocation-simulator adapter: variant/reclaimed state lives ON the
    ``BatchJob`` so ``advance``/``interference_of``/timeline reads see every
    actuation without mirroring."""
    job: Any = None
    name: str = field(init=False)
    table: VariantTable = field(init=False)
    max_reclaim: int = field(init=False)
    n_quanta: int = field(init=False)

    def __post_init__(self):
        self.name = self.job.name
        self.table = self.job.table
        # per-tenant budget from the tenant's OWN chip-groups — NOT from
        # jobs[0]: heterogeneous jobs used to get a wrong shared budget
        self.max_reclaim = self.job.chip_groups - 1
        self.n_quanta = self.job.chip_groups

    @property
    def variant(self) -> int:
        return self.job.variant

    @property
    def reclaimed(self) -> int:
        return self.job.reclaimed

    def set_variant(self, idx: int) -> None:
        assert 0 <= idx < self.n_variants, (idx, self.n_variants)
        self.job.variant = idx

    def reclaim(self, k: int = 1) -> None:
        self.job.reclaimed = min(self.job.reclaimed + k, self.max_reclaim)

    def return_quanta(self, k: int = 1) -> None:
        self.job.reclaimed = max(self.job.reclaimed - k, 0)

    def pressure(self, t: float = 0.0,
                 variant: Optional[int] = None) -> ResourcePressure:
        """The variant's ROOFLINE pressure scaled by the chip share still
        held — deliberately NOT the job's instantaneous phase-modulated
        pressure. The arbiter sees what a deployed controller would know:
        the explorer's compiled-cell profile per variant. Scoring on the
        live phase was measured WORSE (benchmarks/multiapp.py): a victim
        picked at its phase trough looks cheap, then its phase swings up —
        the phase-free profile hedges across phases the way round-robin
        hedges across apps, while still ranking tenants by what they
        structurally exert on each resource."""
        v = self.job.variant if variant is None else variant
        return self.job.table.variants[v].pressure.scaled(
            self.job.chip_frac())
