"""Variant table: the AOT-compiled executable registry (paper §4.2).

Pliant compiles every approximate variant of every approximable function into
ONE binary and swaps function pointers on a Linux signal via DynamoRIO. The
XLA analogue: every variant of ``train_step``/``serve_step`` is jitted and
compiled ONCE up front against the same param pytree; the actuator switches
which executable runs at the next step boundary — an O(µs) dictionary lookup,
no recompilation on the critical path.

Variants are ordered precise-first, increasingly approximate — the order the
Fig-3 controller walks.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.approx.knobs import ApproxKnobs, PRECISE


@dataclass(frozen=True)
class ResourcePressure:
    """Fractions of step time each shared resource is saturated (from the
    dry-run roofline terms: term / bound). Drives the colocation model."""
    hbm: float = 0.8
    ici: float = 0.2
    flops: float = 0.5

    def scaled(self, f: float) -> "ResourcePressure":
        return ResourcePressure(self.hbm * f, self.ici * f, self.flops * f)


@dataclass(frozen=True)
class Variant:
    knobs: ApproxKnobs
    rel_time: float              # step time relative to precise execution
    quality_loss: float          # 0..1 output-quality loss vs precise
    pressure: ResourcePressure = ResourcePressure()

    @property
    def name(self) -> str:
        return self.knobs.describe()


@dataclass
class VariantTable:
    """Ordered: index 0 = precise, last = most approximate."""
    variants: List[Variant]
    executables: Dict[int, Any] = field(default_factory=dict)
    compile_times: Dict[int, float] = field(default_factory=dict)

    def __post_init__(self):
        assert self.variants and self.variants[0].knobs.is_precise(), \
            "variant 0 must be precise execution"

    def __len__(self) -> int:
        return len(self.variants)

    @property
    def most_approximate(self) -> int:
        return len(self.variants) - 1

    def compile_all(self, factory: Callable[[ApproxKnobs], Any],
                    lower: Optional[Callable[[Any], Any]] = None) -> None:
        """factory(knobs) -> step fn; optional lower(step) -> compiled.

        This is the offline 'single binary with all variants' build step.
        """
        for i, v in enumerate(self.variants):
            t0 = time.time()
            step = factory(v.knobs)
            self.executables[i] = lower(step) if lower is not None else step
            self.compile_times[i] = time.time() - t0

    def executable(self, idx: int) -> Any:
        return self.executables[idx]

    def overhead_fraction(self, run_time_s: float) -> float:
        """Instrumentation overhead analogue (DynamoRIO cost in the paper)."""
        return sum(self.compile_times.values()) / max(run_time_s, 1e-9)
