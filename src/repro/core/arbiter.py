"""Multi-tenant arbitration (paper §4.4) behind one interface.

``Arbiter`` owns the per-tenant Fig. 3 hysteresis — violation jumps the
chosen victim straight to its most-approximate variant, then reclaims its
quanta one at a time; slack returns quanta before stepping variants back
toward precise, one move per decision interval — and delegates only WHICH
tenant moves to a victim policy:

* ``RoundRobinArbiter``        — the paper's baseline: cursor order, no app
  penalized disproportionately. Kept as the comparison baseline.
* ``InterferenceAwareArbiter`` — attributes the contended resource from the
  interactive service's sensitivity vector (HBM- vs ICI- vs compute-
  sensitive) weighted by the tenants' live roofline pressures, then picks
  the victim maximizing contended-pressure relieved per unit quality loss
  (PAPERS.md: interference-and-need-aware colocation; CuttleSys per-resource
  attribution). De-approximation runs the same ledger in reverse: quality is
  bought back where it adds the least contended pressure.

Budgets are PER TENANT (``budgets[i]``, defaulting to ``cfg.max_reclaim``):
heterogeneous tenants no longer share one budget sized from the first job.

Both arbiters actuate bound tenants directly (``tenant.set_variant`` /
``reclaim`` / ``return_quanta``) so the simulator and the real serve/train
runtimes share this exact code path — the only fork between them is where
the latency signal comes from.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.core.controller import Action, AppState, ControllerConfig
from repro.core.variants import ResourcePressure

_EPS = 1e-9


@dataclass
class Arbiter:
    """Shared skeleton: Fig. 3 hysteresis over N tenants; subclasses supply
    the four victim-selection policies. ``tenants`` is optional — without it
    the arbiter is a pure decision state machine (the property tests drive
    it that way); with it every decision is actuated immediately."""
    n_variants_per_app: List[int]
    cfg: ControllerConfig = field(default_factory=ControllerConfig)
    tenants: Optional[Sequence] = None
    budgets: Optional[List[int]] = None
    states: List[AppState] = field(init=False)

    def __post_init__(self):
        self.states = [AppState(n) for n in self.n_variants_per_app]

    @classmethod
    def from_tenants(cls, tenants: Sequence, cfg: ControllerConfig, **kw):
        """Bind live tenants: variant counts and per-tenant reclaim budgets
        come from each tenant itself."""
        return cls([t.n_variants for t in tenants], cfg, tenants=tenants,
                   budgets=[t.max_reclaim for t in tenants], **kw)

    # --------------------------------------------------------- bookkeeping --

    def budget(self, i: int) -> int:
        return self.budgets[i] if self.budgets is not None \
            else self.cfg.max_reclaim

    def set_budget(self, i: int, b: int) -> None:
        if self.budgets is None:
            self.budgets = [self.cfg.max_reclaim] * len(self.states)
        self.budgets[i] = b

    def _jumpable(self) -> List[int]:
        return [i for i, s in enumerate(self.states)
                if s.variant < s.most_approx]

    def _reclaimable(self) -> List[int]:
        return [i for i, s in enumerate(self.states)
                if s.reclaimed < self.budget(i)]

    def _returnable(self) -> List[int]:
        return [i for i, s in enumerate(self.states) if s.reclaimed > 0]

    def _steppable(self) -> List[int]:
        return [i for i, s in enumerate(self.states) if s.variant > 0]

    # ----------------------------------------------------------- decisions --

    def tick(self, qos_violated: bool, slack: float, t: float = 0.0
             ) -> Tuple[Action, Optional[int]]:
        """One decision interval. Returns (action, victim index)."""
        if qos_violated:
            i = self.pick_jump(t)
            if i is not None:
                self.states[i].variant = self.states[i].most_approx
                self._apply_variant(i)
                return Action.SET_MOST_APPROX, i
            i = self.pick_reclaim(t)
            if i is not None:
                self.states[i].reclaimed += 1
                self._apply_reclaim(i, +1)
                return Action.RECLAIM_CHIPS, i
            return Action.HOLD, None
        if slack > self.cfg.slack_threshold:
            i = self.pick_return(t)
            if i is not None:
                self.states[i].reclaimed -= 1
                self._apply_reclaim(i, -1)
                return Action.RETURN_CHIPS, i
            i = self.pick_step_precise(t)
            if i is not None:
                self.states[i].variant -= 1
                self._apply_variant(i)
                return Action.STEP_PRECISE, i
        return Action.HOLD, None

    def _apply_variant(self, i: int) -> None:
        if self.tenants is not None:
            self.tenants[i].set_variant(self.states[i].variant)

    def _apply_reclaim(self, i: int, d: int) -> None:
        if self.tenants is not None:
            if d > 0:
                self.tenants[i].reclaim(1)
            else:
                self.tenants[i].return_quanta(1)

    # ----------------------------------------------------- victim policies --

    def pick_jump(self, t: float) -> Optional[int]:
        raise NotImplementedError

    def pick_reclaim(self, t: float) -> Optional[int]:
        raise NotImplementedError

    def pick_return(self, t: float) -> Optional[int]:
        raise NotImplementedError

    def pick_step_precise(self, t: float) -> Optional[int]:
        raise NotImplementedError


@dataclass
class RoundRobinArbiter(Arbiter):
    """Paper §4.4 baseline: approximate one app at a time in cursor order;
    only when ALL run most-approximate, reclaim quanta one app and one
    quantum at a time — no app penalized disproportionately."""
    start: int = 0                  # paper: first victim selected randomly
    _cursor: int = field(init=False)

    def __post_init__(self):
        super().__post_init__()
        self._cursor = self.start % len(self.states)

    def _next(self, candidates: List[int]) -> Optional[int]:
        n = len(self.states)
        cset = set(candidates)
        for d in range(n):
            i = (self._cursor + d) % n
            if i in cset:
                self._cursor = (i + 1) % n
                return i
        return None

    def pick_jump(self, t: float) -> Optional[int]:
        return self._next(self._jumpable())

    def pick_reclaim(self, t: float) -> Optional[int]:
        return self._next(self._reclaimable())

    def pick_return(self, t: float) -> Optional[int]:
        return self._next(self._returnable())

    def pick_step_precise(self, t: float) -> Optional[int]:
        return self._next(self._steppable())


@dataclass
class InterferenceAwareArbiter(Arbiter):
    """Resource-attributed victim selection, asymmetric like Fig. 3 itself:
    under violation, relieve the contended resource as fast as possible
    (jump the victim with the largest absolute relief; reclaim where each
    quantum sheds the most); under slack, buy quality back where it costs
    the least contended pressure (step-precise by quality gained per unit
    pressure added; return quanta where regrowth adds the least).

    ``sensitivity`` is the interactive service's per-resource sensitivity
    vector (``ServiceProfile.sensitivity``; reusing ``ResourcePressure`` as
    the vector type). Each decision first ATTRIBUTES the contended resource:
    the axis maximizing ``sensitivity_axis * sum_j pressure_j.axis`` — the
    resource the service both cares about and the tenants are saturating —
    then scores moves on that axis alone (CuttleSys-style per-resource
    attribution rather than a scalar interference blob).

    Requires bound tenants (their ``pressure(t, variant)`` supplies the
    roofline terms; ``n_quanta`` scales per-quantum relief)."""
    sensitivity: ResourcePressure = field(
        default_factory=lambda: ResourcePressure(hbm=0.6, ici=0.25,
                                                 flops=0.15))

    def __post_init__(self):
        super().__post_init__()
        assert self.tenants is not None, \
            "InterferenceAwareArbiter needs bound tenants for pressures"

    # ------------------------------------------------------- attribution --

    def contended_axis(self, t: float) -> str:
        """Attribute contention to one resource: sensitivity-weighted
        aggregate tenant pressure, highest axis wins."""
        agg = {"hbm": 0.0, "ici": 0.0, "flops": 0.0}
        for tn in self.tenants:
            p = tn.pressure(t)
            agg["hbm"] += p.hbm
            agg["ici"] += p.ici
            agg["flops"] += p.flops
        w = {"hbm": self.sensitivity.hbm * agg["hbm"],
             "ici": self.sensitivity.ici * agg["ici"],
             "flops": self.sensitivity.flops * agg["flops"]}
        return max(w, key=lambda a: (w[a], a))

    def _axis_pressure(self, i: int, t: float, axis: str,
                       variant: Optional[int] = None) -> float:
        return getattr(self.tenants[i].pressure(t, variant), axis)

    # --------------------------------------------------- victim policies --

    def pick_jump(self, t: float) -> Optional[int]:
        """Most ABSOLUTE contended pressure relieved by a jump to
        most-approximate. Under violation the scarce resource is time, not
        quality: any victim jumped now is stepped back during slack on the
        same ledger, so exiting violation in the fewest intervals wins —
        quality-normalizing this score (relief per unit loss) was measured
        to pick efficient-but-small reliefs that leave the service
        violating longer (benchmarks/multiapp.py round-robin comparison)."""
        cands = self._jumpable()
        if not cands:
            return None
        axis = self.contended_axis(t)

        def score(i):
            s = self.states[i]
            return (self._axis_pressure(i, t, axis, s.variant)
                    - self._axis_pressure(i, t, axis, s.most_approx))

        return max(cands, key=lambda i: (score(i), -i))

    def pick_reclaim(self, t: float) -> Optional[int]:
        """Most contended pressure relieved per reclaimed quantum (a tenant
        on n quanta sheds ~pressure/n per quantum); quality loss is zero for
        all candidates (reclaiming slows, it does not approximate)."""
        cands = self._reclaimable()
        if not cands:
            return None
        axis = self.contended_axis(t)
        return max(cands, key=lambda i: (
            self._axis_pressure(i, t, axis)
            / max(self.tenants[i].n_quanta, 1), -i))

    def pick_return(self, t: float) -> Optional[int]:
        """Return quanta where regrowth adds the LEAST contended pressure —
        the heaviest contender stays throttled longest."""
        cands = self._returnable()
        if not cands:
            return None
        axis = self.contended_axis(t)
        return min(cands, key=lambda i: (
            self._axis_pressure(i, t, axis)
            / max(self.tenants[i].n_quanta, 1), i))

    def pick_step_precise(self, t: float) -> Optional[int]:
        """Most quality recovered per unit contended pressure added by one
        step toward precise."""
        cands = self._steppable()
        if not cands:
            return None
        axis = self.contended_axis(t)

        def score(i):
            s = self.states[i]
            gain = (self.tenants[i].quality_loss(s.variant)
                    - self.tenants[i].quality_loss(s.variant - 1))
            added = (self._axis_pressure(i, t, axis, s.variant - 1)
                     - self._axis_pressure(i, t, axis, s.variant))
            return gain / max(added, _EPS)

        return max(cands, key=lambda i: (score(i), -i))
