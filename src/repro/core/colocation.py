"""Colocation contention model + decision-interval simulator.

This container cannot physically produce cross-tenant interference on a TPU
pod, so the *latency signal source* is a calibrated queueing model; monitor,
arbiter, and tenant actuation are the REAL runtime code paths (DESIGN.md
§2, §11) — each job is wrapped in a ``core.tenant.SimTenant`` and driven by
the same ``core.arbiter`` classes that drive the serve/train runtimes. The
batch job's resource *pressures* (fraction of step time saturating
HBM / ICI / MXU) come from the compiled dry-run's roofline terms per variant.

Model:
    rho      = offered_load / capacity_boost(reclaimed chips)
    interf   = sum_j chip_share_j * (s_mem * hbm_j + s_ici * ici_j)
    p99      = p99_iso(rho) * (1 + interf / (1 - rho))
    p99_iso  = service_time * (1 + c_q / (1 - rho))

Three interactive-service profiles mirror the paper's (strict / moderate /
lenient): per-token LLM decode ("memcached-like"), interactive search prefill
("NGINX-like"), and a batch-embedding API ("MongoDB-like").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.arbiter import InterferenceAwareArbiter, RoundRobinArbiter
from repro.core.controller import ControllerConfig
from repro.core.monitor import LatencyMonitor
from repro.core.tenant import SimTenant
from repro.core.variants import ResourcePressure, VariantTable


@dataclass(frozen=True)
class ServiceProfile:
    name: str
    qos_target_s: float
    service_time_s: float        # base per-request service time
    c_q: float                   # queueing-curve constant
    sens_mem: float              # sensitivity to HBM-bandwidth pressure
    sens_ici: float              # sensitivity to ICI pressure
    qps_at_saturation: float
    chips_boost: float = 0.045   # capacity gain per reclaimed chip-group
    sens_flops: float = 0.05     # sensitivity to MXU/compute pressure (the
                                 # p99 model is mem+ici; this only steers
                                 # the arbiter's contention attribution)

    @property
    def sensitivity(self) -> ResourcePressure:
        """The service's per-resource sensitivity vector, in the same
        ``ResourcePressure`` coordinates the tenants report pressure in —
        the interference-aware arbiter's attribution input."""
        return ResourcePressure(hbm=self.sens_mem, ici=self.sens_ici,
                                flops=self.sens_flops)

    def p99_iso(self, rho: float) -> float:
        rho = min(rho, 0.995)
        return self.service_time_s * (1.0 + self.c_q / (1.0 - rho))

    def p99(self, load_frac: float, interference: float,
            reclaimed_groups: int) -> float:
        boost = 1.0 + self.chips_boost * reclaimed_groups
        rho = min(load_frac / boost, 0.995)
        return self.p99_iso(rho) * (1.0 + interference / (1.0 - rho))


# Calibrated so precise-mode colocation violates QoS by the paper's bands
# (memcached 1.46-3.8x, NGINX 2.1-9.8x, MongoDB 2.08-5.91x) at 75-80% load —
# asserted in tests/test_colocation.py.
SERVICES = {
    # strict per-token decode SLA; decode is HBM-bound -> high mem sensitivity
    "token-serve": ServiceProfile(
        "token-serve", qos_target_s=0.020, service_time_s=0.0028, c_q=0.9,
        sens_mem=0.60, sens_ici=0.25, qps_at_saturation=48_000.0),
    # interactive search/prefill: balanced compute+collective sensitivity
    "search-prefill": ServiceProfile(
        "search-prefill", qos_target_s=0.250, service_time_s=0.036, c_q=0.9,
        sens_mem=0.42, sens_ici=0.50, qps_at_saturation=3_200.0),
    # offline-ish embedding API: large latency budget, mild sensitivity
    "embed-api": ServiceProfile(
        "embed-api", qos_target_s=1.500, service_time_s=0.30, c_q=0.55,
        sens_mem=0.30, sens_ici=0.12, qps_at_saturation=310.0),
}

# paper analogue mapping (DESIGN.md §2)
PAPER_ANALOGUE = {"token-serve": "memcached", "search-prefill": "NGINX",
                  "embed-api": "MongoDB"}

# Three contention ARCHETYPES for the arbiter comparison (dry-run-shaped
# baseline roofline terms): an HBM-bound dense job, an ICI-bound MoE job
# (all-to-all dominant), and a compute-bound SSM job. Victim selection only
# matters when tenants press on DIFFERENT resources — the stock analytic
# baseline gives every train job near-identical pressure ratios, which
# would measure nothing but noise.
CONTENTION_ARCHETYPES = {
    "phi4-mini-3.8b": dict(compute_s=0.8, memory_s=1.6, collective_s=0.3),
    "olmoe-1b-7b": dict(compute_s=0.9, memory_s=0.7, collective_s=1.7),
    "mamba2-780m": dict(compute_s=1.5, memory_s=0.8, collective_s=0.25),
}


_ARCHETYPE_TABLES: dict = {}


def archetype_jobs(total_work: float = 5000.0) -> List["BatchJob"]:
    """The heterogeneous steady-state mix the round-robin vs interference-
    aware comparison runs on (tests + benchmarks/multiapp.py). ``total_work``
    outlasts the horizon so the two arbiters are compared over identical
    denominators — a faster-finishing mix would pad its own met-fraction
    with quiet tail intervals. Tables are deterministic, so they are
    explored once and shared; only the (mutable-state) BatchJobs are fresh
    per call."""
    if not _ARCHETYPE_TABLES:
        from repro.configs import SHAPES, get_config
        from repro.core.explorer import explore
        for arch, art in CONTENTION_ARCHETYPES.items():
            _ARCHETYPE_TABLES[arch] = explore(
                get_config(arch), SHAPES["train_4k"], baseline_art=art)
    rng = np.random.default_rng(5)
    return [BatchJob(arch, _ARCHETYPE_TABLES[arch], total_work=total_work,
                     phase_offset=float(rng.uniform(0, 2 * np.pi)),
                     phase_period=float(rng.uniform(50, 120)))
            for arch in CONTENTION_ARCHETYPES]


@dataclass
class BatchJob:
    name: str
    table: VariantTable
    total_work: float = 300.0        # nominal seconds of precise execution
    variant: int = 0
    chip_groups: int = 16            # one data-axis slice per group (16x16 pod)
    reclaimed: int = 0
    work_done: float = 0.0
    weighted_loss: float = 0.0       # integral of qloss over work
    finished_at: Optional[float] = None
    # execution phases (paper: e.g. canneal only contends in some phases) —
    # pressure swings between (1 - phase_amp) and 1 with period phase_period
    phase_amp: float = 0.75
    phase_period: float = 80.0
    phase_offset: float = 0.0

    def pressure(self, t: float = 0.0) -> ResourcePressure:
        v = self.table.variants[self.variant]
        m = 1.0 - self.phase_amp * (0.5 + 0.5 * float(
            np.sin(2 * np.pi * (t / self.phase_period) + self.phase_offset)))
        return v.pressure.scaled(m)

    def chip_frac(self) -> float:
        return max(self.chip_groups - self.reclaimed, 0) / self.chip_groups

    def advance(self, dt: float, now: float) -> None:
        if self.finished_at is not None:
            return
        v = self.table.variants[self.variant]
        speed = self.chip_frac() / max(v.rel_time, 1e-6)
        dw = dt * speed
        self.work_done += dw
        self.weighted_loss += dw * v.quality_loss
        if self.work_done >= self.total_work:
            self.finished_at = now

    @property
    def quality_loss(self) -> float:
        return self.weighted_loss / max(self.work_done, 1e-9)


@dataclass
class TimelinePoint:
    t: float
    p99: float
    variants: Tuple[int, ...]
    reclaimed: Tuple[int, ...]
    action: str


@dataclass
class SimResult:
    timeline: List[TimelinePoint]
    service: ServiceProfile
    jobs: List[BatchJob]

    @property
    def qos_met_frac(self) -> float:
        return float(np.mean([p.p99 <= self.service.qos_target_s
                              for p in self.timeline]))

    def exec_time(self, j: int = 0) -> float:
        job = self.jobs[j]
        return job.finished_at if job.finished_at is not None \
            else self.timeline[-1].t

    @property
    def max_reclaimed(self) -> Tuple[int, ...]:
        return tuple(int(np.max([p.reclaimed[i] for p in self.timeline]))
                     for i in range(len(self.jobs)))


def interference_of(jobs: Sequence[BatchJob], svc: ServiceProfile,
                    t: float = 0.0) -> float:
    total = 0.0
    n = max(len(jobs), 1)
    for job in jobs:
        if job.finished_at is not None:
            continue
        p = job.pressure(t)
        total += (job.chip_frac() / n) * (svc.sens_mem * p.hbm
                                          + svc.sens_ici * p.ici)
    return total


def simulate(service: ServiceProfile, jobs: List[BatchJob], *,
             load_frac: float = 0.775, horizon_s: float = 420.0,
             interval_s: float = 1.0, precise_only: bool = False,
             seed: int = 0, slack_threshold: float = 0.10,
             samples_per_interval: int = 2000,
             arbiter: str = "round_robin") -> SimResult:
    """Decision-interval simulation of one colocation.

    ``arbiter`` selects the victim policy over the SAME arbiter code path
    the real serve/train runtimes use (``core/arbiter.py``): the paper's
    ``"round_robin"`` baseline, or ``"interference"`` — contended-resource
    attribution from the service's sensitivity vector, victims scored by
    contended pressure relieved (violation side) and by quality recovered
    per pressure added (slack side). Reclaim budgets are per tenant (each
    job's own ``chip_groups - 1``), not sized from ``jobs[0]``.
    """
    rng = np.random.default_rng(seed)
    monitor = LatencyMonitor(service.qos_target_s,
                             window=2 * samples_per_interval)
    # no max_reclaim here: budgets are PER TENANT (from_tenants reads each
    # SimTenant's chip_groups - 1) — sizing a shared one from jobs[0] was
    # exactly the heterogeneous-jobs bug this field would re-invite
    cfg = ControllerConfig(slack_threshold=slack_threshold,
                           decision_interval_s=interval_s)
    multi = len(jobs) > 1
    tenants = [SimTenant(j) for j in jobs]
    if arbiter == "interference":
        ctl = InterferenceAwareArbiter.from_tenants(
            tenants, cfg, sensitivity=service.sensitivity)
    elif arbiter == "round_robin":
        # paper: first victim selected randomly (single-job sims skip the
        # draw so their noise streams match the historical calibration)
        ctl = RoundRobinArbiter.from_tenants(
            tenants, cfg, start=int(rng.integers(len(jobs))) if multi else 0)
    else:
        raise ValueError(f"unknown arbiter {arbiter!r}")

    timeline: List[TimelinePoint] = []
    t = 0.0
    sigma = 0.35
    while t < horizon_s and any(j.finished_at is None for j in jobs):
        interf = interference_of(jobs, service, t)
        reclaimed_total = sum(j.reclaimed for j in jobs)
        p99_true = service.p99(load_frac, interf, reclaimed_total)
        # generate request latencies whose p99 matches the model
        med = p99_true / float(np.exp(2.326 * sigma))
        lat = med * np.exp(sigma * rng.standard_normal(samples_per_interval))
        monitor.record_many(lat)
        # control acts on the (sampled, noisy) monitor estimate — realistic;
        # the timeline records the REALIZED p99 the interval's requests saw.
        p99_real = float(np.percentile(lat, 99))

        action = "hold"
        if not precise_only:
            # consume the decision window (act on fresh data next interval);
            # below min_samples the estimator abstains -> realized fallback
            p99_mon, _, _ = monitor.consume_window()
            p99_obs = p99_mon if p99_mon is not None else p99_real
            violated = p99_obs > service.qos_target_s
            slack = (service.qos_target_s - p99_obs) / service.qos_target_s
            # the arbiter actuates the SimTenants directly — the same code
            # path PliantRuntime drives for the real serve/train tenants
            act, idx = ctl.tick(violated, slack, t=t)
            action = f"{act.value}:{idx}" if (multi and idx is not None) \
                else act.value

        for j in jobs:
            j.advance(interval_s, t + interval_s)
        timeline.append(TimelinePoint(
            t=t, p99=p99_real,
            variants=tuple(j.variant for j in jobs),
            reclaimed=tuple(j.reclaimed for j in jobs),
            action=action))
        t += interval_s
    return SimResult(timeline, service, jobs)
