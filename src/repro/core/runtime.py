"""PliantRuntime: monitor -> arbiter -> tenant glue for REAL runs.

A thin shell over an ``Arbiter`` and a tenant list: every decision interval
(wall-clock deadline — a straggling step cannot delay control decisions, the
runtime simply acts at the next boundary) it consumes the monitor's window
and lets the arbiter pick and actuate one victim move. All actuation goes
through the ``Tenant`` protocol (``core/tenant.py``): executable hot-swap,
chip-group reshard, page-pool reclaim — the runtime no longer special-cases
any of them.

Backward-compatible single-tenant construction: ``PliantRuntime(table,
monitor, cfg, reshard_fn=...)`` wraps the table in a ``TrainTenant`` (budget
0 without a reshard actuator, so the controller never burns intervals on
phantom RECLAIM/RETURN actions) under a single-tenant round-robin arbiter —
which is exactly the Fig. 3 ``PliantController`` policy. Multi-tenant:
``PliantRuntime(monitor=m, cfg=c, tenants=[...], arbiter=...)``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, List, Optional

from repro.core.arbiter import Arbiter, RoundRobinArbiter
from repro.core.controller import Action, ControllerConfig
from repro.core.monitor import LatencyMonitor
from repro.core.tenant import Tenant, TrainTenant
from repro.core.variants import VariantTable


@dataclass
class PliantRuntime:
    table: Optional[VariantTable] = None
    monitor: LatencyMonitor = None
    cfg: ControllerConfig = field(default_factory=ControllerConfig)
    reshard_fn: Optional[Callable[[int], None]] = None   # reclaimed groups
    tenants: Optional[List[Tenant]] = None
    arbiter: Optional[Arbiter] = None
    _last_decision: float = field(init=False)
    _auto_tenant: bool = field(init=False, default=False)
    history: Deque[dict] = field(init=False)

    def __post_init__(self):
        if self.tenants is None:
            assert self.table is not None, \
                "PliantRuntime needs a table (single-tenant) or tenants"
            budget = self.cfg.max_reclaim if self.reshard_fn is not None \
                else 0
            self.tenants = [TrainTenant(self.table, reshard_fn=self.reshard_fn,
                                        max_reclaim=budget)]
            self._auto_tenant = True
            self._sync_cfg_budget()
        elif self.table is None:
            self.table = self.tenants[0].table
        if self.arbiter is None:
            self.arbiter = RoundRobinArbiter.from_tenants(self.tenants,
                                                          self.cfg)
        self.history = collections.deque(maxlen=self.cfg.history_limit)
        self._last_decision = time.monotonic()
        self._capacity_out = 0      # outstanding PRESSURE_ON capacity events
        self.capacity_log: List[dict] = []

    def _sync_cfg_budget(self) -> None:
        """Single-tenant compat: ``cfg.max_reclaim`` mirrors the tenant's
        own budget (callers/tests read it as THE reclaim budget)."""
        if len(self.tenants) == 1 \
                and self.tenants[0].max_reclaim != self.cfg.max_reclaim:
            self.cfg = dataclasses.replace(
                self.cfg, max_reclaim=self.tenants[0].max_reclaim)
            if self.arbiter is not None:
                self.arbiter.cfg = self.cfg

    # ------------------------------------------------------------- binding --

    def bind(self, tenant: Tenant, index: int = 0) -> None:
        """Replace a tenant (the auto-built placeholder, usually) with a
        real adapter — e.g. the serve engine binding itself at construction.
        Rebuilds the arbiter, so it is construction-time only: after any
        decision the arbiter's variant/reclaimed ledger and the tenants'
        actuated state would silently diverge (reclaimed quanta never
        returned)."""
        from repro.core.arbiter import InterferenceAwareArbiter
        assert not self.history, \
            "bind() after decisions would discard the arbiter ledger"
        self.tenants[index] = tenant
        kw = {}
        if isinstance(self.arbiter, RoundRobinArbiter):
            kw["start"] = self.arbiter.start
        if isinstance(self.arbiter, InterferenceAwareArbiter):
            kw["sensitivity"] = self.arbiter.sensitivity
        self.arbiter = type(self.arbiter).from_tenants(self.tenants,
                                                       self.cfg, **kw)
        self._auto_tenant = False
        if index == 0 and tenant.table is not None:
            self.table = tenant.table
        self._sync_cfg_budget()

    @property
    def auto_tenant(self) -> bool:
        """True while tenant 0 is the constructor's placeholder wrap."""
        return self._auto_tenant

    def attach_reclaimer(self, fn: Callable[[int], None],
                         max_reclaim: Optional[int] = None) -> None:
        """Late-bind a reclaim actuator on tenant 0 and restore its budget
        (construction order often puts the actuator after the runtime).
        ``fn(k)`` receives the ABSOLUTE reclaimed-quanta count on every
        RECLAIM/RETURN, whatever adapter tenant 0 is (a bound ServeTenant
        chains it after its own pool actuation)."""
        self.reshard_fn = fn
        self.tenants[0].rebind(fn, max_reclaim)
        if max_reclaim is not None:
            self.arbiter.set_budget(0, self.tenants[0].max_reclaim)
            self._sync_cfg_budget()

    # --------------------------------------------------------------- state --

    @property
    def active_variant(self) -> int:
        return self.arbiter.states[0].variant

    @property
    def reclaimed(self) -> int:
        return self.arbiter.states[0].reclaimed

    def step_executable(self) -> Any:
        return self.table.executable(self.active_variant)

    # ------------------------------------------------------------ capacity --

    def notify_capacity(self, ev) -> None:
        """A ``dist.elastic.CapacityEvent`` is a CONTENTION SOURCE: while
        any revocation or quota cut is outstanding, every decision tick sees
        the violation arm of the Fig. 3 hysteresis — the arbiter
        de-approximates / reclaims from victims exactly as it does under QoS
        pressure, and a restore lets the slack arm walk tenants back toward
        precise. The arbiter itself is unchanged; deflation simply enters
        the loop through the same gate as a p99 violation."""
        from repro.dist import elastic
        if ev.kind in elastic.PRESSURE_ON:
            self._capacity_out += 1
        elif ev.kind in elastic.PRESSURE_OFF:
            self._capacity_out = max(self._capacity_out - 1, 0)
        self.capacity_log.append(dict(t=time.monotonic(), kind=ev.kind,
                                      outstanding=self._capacity_out))

    @property
    def capacity_pressure(self) -> bool:
        return self._capacity_out > 0

    def inject(self, ev) -> None:
        """Fleet-level fault entry point (colocate/train drivers): record
        the event as contention pressure here, then fan it out to every
        tenant's ``on_capacity`` actuator (the serve adapter re-homes its
        engine, the train adapter reshards mid-flight)."""
        self.notify_capacity(ev)
        for t in self.tenants:
            t.on_capacity(ev)

    # ----------------------------------------------------------- decisions --

    def maybe_decide(self, now: Optional[float] = None) -> Optional[Action]:
        """Deadline-based decision tick; call once per batch step boundary."""
        now = time.monotonic() if now is None else now
        if now - self._last_decision < self.cfg.decision_interval_s:
            return None
        self._last_decision = now
        # one reset-window convention for every control plane (sim included):
        # read the closing window, act on it, start the next one fresh
        _, violated, slack = self.monitor.consume_window()
        if self.capacity_pressure:
            # outstanding capacity loss: force the violation arm (and mask
            # any slack reading — returning quanta while deflated would
            # fight the revocation)
            violated, slack = True, False
        action, victim = self.arbiter.tick(violated, slack, t=now)
        self.history.append({
            "t": now, "action": action.value, "victim": victim,
            "variant": self.active_variant, "reclaimed": self.reclaimed,
            "variants": tuple(s.variant for s in self.arbiter.states),
            "reclaimed_all": tuple(s.reclaimed
                                   for s in self.arbiter.states),
            "violated": violated, "slack": slack,
            "capacity": self._capacity_out})
        return action
