"""PliantRuntime: monitor -> controller -> actuator glue for REAL runs.

Used by ``launch/train.py`` and the examples: the batch job executes its
current variant's compiled step; every decision interval (wall-clock deadline
— a straggling step cannot delay control decisions, the controller simply
acts at the next boundary) the controller reads the monitor and the actuator
switches the executable and/or triggers elastic chip reclamation via the
provided ``reshard_fn``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

from repro.core.controller import (Action, ControllerConfig, PliantController)
from repro.core.monitor import LatencyMonitor
from repro.core.variants import VariantTable


@dataclass
class PliantRuntime:
    table: VariantTable
    monitor: LatencyMonitor
    cfg: ControllerConfig = field(default_factory=ControllerConfig)
    reshard_fn: Optional[Callable[[int], None]] = None   # reclaimed groups
    controller: PliantController = field(init=False)
    _last_decision: float = field(init=False)
    history: List[dict] = field(default_factory=list)

    def __post_init__(self):
        if self.reshard_fn is None and self.cfg.max_reclaim:
            # no actuator for chip reclamation: without this cap the
            # controller burns decision intervals on phantom RECLAIM/RETURN
            # actions before it will step back toward precise
            import dataclasses
            self.cfg = dataclasses.replace(self.cfg, max_reclaim=0)
        self.controller = PliantController(len(self.table), self.cfg)
        self._last_decision = time.monotonic()

    def attach_reclaimer(self, fn: Callable[[int], None],
                         max_reclaim: Optional[int] = None) -> None:
        """Late-bind a reclaim actuator and restore the reclaim budget.

        Construction order often puts the actuator after the runtime (the
        serve engine builds its page pool with the runtime already in hand),
        so ``__post_init__`` has zeroed ``max_reclaim`` by the time the
        actuator exists. ``fn(k)`` is called with the controller's current
        reclaimed-quanta count — chip-groups for train jobs (``reshard_fn``),
        page-pool quanta for paged serving (``PagePool.set_reclaimed``).
        """
        import dataclasses
        self.reshard_fn = fn
        if max_reclaim is not None:
            self.cfg = dataclasses.replace(self.cfg, max_reclaim=max_reclaim)
            self.controller.cfg = self.cfg

    @property
    def active_variant(self) -> int:
        return self.controller.state.variant

    @property
    def reclaimed(self) -> int:
        return self.controller.state.reclaimed

    def step_executable(self) -> Any:
        return self.table.executable(self.active_variant)

    def maybe_decide(self, now: Optional[float] = None) -> Optional[Action]:
        """Deadline-based decision tick; call once per batch step boundary."""
        now = time.monotonic() if now is None else now
        if now - self._last_decision < self.cfg.decision_interval_s:
            return None
        self._last_decision = now
        violated = self.monitor.qos_violated()
        slack = self.monitor.slack()
        before = self.reclaimed
        action = self.controller.tick(violated, slack)
        if action in (Action.RECLAIM_CHIPS, Action.RETURN_CHIPS) \
                and self.reshard_fn is not None:
            self.reshard_fn(self.reclaimed)
        self.history.append({
            "t": now, "action": action.value, "variant": self.active_variant,
            "reclaimed": self.reclaimed, "violated": violated,
            "slack": slack})
        self.monitor.reset_window()
        return action
