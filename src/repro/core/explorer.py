"""Offline approximation design-space exploration (paper §3 + Fig. 1).

Per architecture family we enumerate the applicable knob grid (the ACCEPT-
hints analogue: the framework knows which "loops" each family has), evaluate
(execution time, inaccuracy) per candidate, prune to the Pareto frontier, and
keep only variants under the tolerable quality-loss threshold (default 5%).

Two evaluation backends:
* ``analytic``  — cost from the roofline model (FLOPs/bytes/wire deltas per
  knob) and quality from a calibrated per-knob loss model. Fast; used for
  full-size archs where a measurement would need the real pod.
* ``measured``  — real step timing + real quality measurement on the reduced
  (smoke) config: short training runs for train jobs, logit agreement for
  serving jobs. Used by the Fig. 1 benchmark.
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.approx.knobs import ApproxKnobs, PRECISE
from repro.configs.base import ModelConfig
from repro.core.variants import ResourcePressure, Variant, VariantTable


def knob_grid(cfg: ModelConfig, *, serving: bool = False) -> List[ApproxKnobs]:
    """Family-aware candidate enumeration (DESIGN.md §Arch-applicability)."""
    precisions = ["bf16", "int8"]
    drops = [0.0, 0.125, 0.25, 0.5]
    skips = [0.0, 0.25]
    strides = [1]
    topks = [0]
    if any(k in ("attn", "local") for k in cfg.kinds()) and not serving:
        strides = [1, 2, 4]
    if cfg.moe is not None:
        t = cfg.moe.top_k
        topks = [0] + sorted({max(1, t // 2), max(1, 3 * t // 4),
                              max(1, t // 4)})
    syncs = [1, 2, 4] if not serving else [1]
    compresses = ["none", "int8"] if not serving else ["none"]
    # serving-only knob: int8 KV cache (orthogonal to matmul precision)
    kv_quants = [False, True] if serving else [False]
    cands = []
    for p, d, s, st, tk, sy, gc, kvq in itertools.product(
            precisions, drops, skips, strides, topks, syncs, compresses,
            kv_quants):
        if serving and (d or s):      # no token/layer drop for serving jobs
            continue
        if gc != "none" and sy > 1:
            # sync elision already removes the per-step pod reduce that
            # compression would shrink (train/step.grad_reduce_for); the
            # combination executes identically to sync-only, so don't
            # enumerate it as a distinct variant
            continue
        # at most two techniques per variant — the paper's variants perforate
        # one loop / lower one type at a time (Fig. 1 spaces), not the full
        # cross-product; this also keeps top-end quality loss near the
        # measured 2-3% band instead of saturating the 5% cap
        active = sum([p != "bf16", d > 0, s > 0, st > 1, tk > 0, sy > 1,
                      gc != "none", kvq])
        if active > 2:
            continue
        cands.append(ApproxKnobs(matmul_precision=p, token_drop=d,
                                 layer_skip=s, kv_keep_stride=st,
                                 topk_override=tk, sync_period=sy,
                                 grad_compress=gc, kv_quant=kvq))
    # dedupe, precise first
    seen, out = set(), []
    for k in [PRECISE] + cands:
        if k not in seen:
            seen.add(k)
            out.append(k)
    return out


# --------------------------------------------------- analytic evaluation --

# calibrated per-knob quality-loss contributions (fractions), fit from the
# measured smoke-scale sweeps (benchmarks/pareto.py) — see EXPERIMENTS.md.
# fit from benchmarks/pareto.py measured smoke sweeps (results/bench/
# pareto_*.json): drop50 ~= 0.9-1.1%, topk-half ~= 1.0%, int8 <= 0.3%;
# layer_skip kept conservative (toy depth underestimates real-depth loss).
_QUALITY = {
    "int8": 0.003,
    "token_drop": 0.022,       # x drop fraction
    "layer_skip": 0.08,        # x skip fraction
    "kv_stride": 0.008,        # x (1 - 1/stride)
    "topk": 0.022,             # x (1 - k/k0)
    "sync": 0.012,             # x (1 - 1/period)
    "grad_compress": 0.004,    # int8 gradient wire noise, consumed per step
    "kv_quant": 0.003,
}


def analytic_quality_loss(cfg: ModelConfig, k: ApproxKnobs) -> float:
    q = 0.0
    if k.matmul_precision == "int8":
        q += _QUALITY["int8"]
    q += _QUALITY["token_drop"] * k.token_drop
    q += _QUALITY["layer_skip"] * k.layer_skip
    if k.kv_keep_stride > 1:
        q += _QUALITY["kv_stride"] * (1 - 1.0 / k.kv_keep_stride)
    if k.topk_override and cfg.moe is not None:
        q += _QUALITY["topk"] * (1 - k.topk_override / cfg.moe.top_k)
    if k.sync_period > 1:
        q += _QUALITY["sync"] * (1 - 1.0 / k.sync_period)
    if k.grad_compress != "none" and k.sync_period == 1:
        # under sync elision the per-step compressed reduce never runs
        # (train/step.grad_reduce_for), so its noise contributes nothing
        q += _QUALITY["grad_compress"]
    if k.kv_quant:
        q += _QUALITY["kv_quant"]
    return q


def decode_kv_share(cfg: ModelConfig, batch: int, max_len: int, *,
                    dtype=None, quantized: bool = False) -> float:
    """KV-cache share of one dense decode step's HBM bytes, derived from the
    COMPILED decode cell's ``cost_analysis()`` (the dry-run's roofline input)
    rather than the old hard-coded 0.5 heuristic.

    The ring bytes are exact (every attention layer streams its full
    ``(B, W, G, hd)`` K+V rings once per token); the denominator is the
    executable's total bytes accessed. This is what makes paged decode
    pricing honest: the fused paged kernel streams LIVE pages instead of the
    rings, so the memory term scales by ``kv_share * occupancy`` — and
    ``kv_share`` must come from the real executable, not a guess.
    """
    import jax
    import jax.numpy as jnp
    from repro.models import api
    from repro.train import step as step_mod
    dtype = dtype or jnp.float32
    step = step_mod.make_serve_step(cfg, PRECISE)
    params = api.abstract(cfg, dtype)
    # caches at the SAME dtype as the ring-bytes numerator below — a dtype
    # mismatch here (e.g. bf16 caches under an fp32 numerator) silently
    # doubles the share this function exists to make honest
    caches = api.abstract_caches(cfg, batch, max_len, quantized=quantized,
                                 dtype=dtype)
    toks = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
    compiled = jax.jit(step).lower(params, toks, pos, caches).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # jax<=0.4.x drift (see dryrun)
        cost = cost[0] if cost else {}
    total = float(cost.get("bytes accessed", 0.0))
    from repro.configs.base import LOCAL_ATTN, MAMBA
    itemsize = 1 if quantized else jnp.dtype(dtype).itemsize
    hd, G = cfg.resolved_head_dim, cfg.n_kv_heads
    ring = 0
    for kind in cfg.kinds():
        if kind == MAMBA:
            continue
        W = min(cfg.window, max_len) if kind == LOCAL_ATTN and cfg.window \
            else max_len
        ring += 2 * batch * W * G * hd * itemsize      # K + V read per step
    if total <= 0 or ring <= 0:
        return 0.5                           # analytic fallback
    return min(ring / total, 0.95)


def analytic_cost(cfg: ModelConfig, shape, k: ApproxKnobs,
                  baseline_art: Optional[dict] = None, *,
                  page_occupancy: Optional[float] = None,
                  kv_share: Optional[float] = None
                  ) -> Tuple[float, ResourcePressure]:
    """(rel_time, pressure) from the roofline model.

    If a dry-run artifact for the precise variant is given, its three terms
    anchor the baseline; knob deltas scale each term analytically.

    ``page_occupancy`` (paged serving engines): fraction of the dense cache
    footprint that is live pages. Dense decode streams the full ``max_len``
    rings every step; a paged pool (fused kernel) streams only mapped pages,
    so the KV share of the decode memory term scales by occupancy — the
    frontier then sees paged memory savings exactly like any other
    memory-side knob. ``kv_share`` is that KV share of decode HBM bytes,
    ideally from ``decode_kv_share`` (compiled-cell ``cost_analysis``);
    None falls back to the coarse 0.5 heuristic.
    """
    from repro import roofline
    if baseline_art is not None:
        comp = baseline_art["compute_s"]
        mem = baseline_art["memory_s"]
        coll = baseline_art["collective_s"]
    else:
        mf = roofline.model_flops(cfg, shape, PRECISE)
        comp = mf / 256 / roofline.PEAK_FLOPS
        # decode streams every weight + the KV rings per emitted token at
        # trivial arithmetic intensity: firmly HBM-bound, so memory-side knobs
        # (int8 weights, kv_quant) keep paying off after compute knobs bind
        mem = comp * (4.0 if shape.kind == "decode" else 1.2)
        coll = comp * 0.3
    # knob effects on each term
    f_tok = 1.0 - k.token_drop
    f_layer = 1.0 - 0.9 * k.layer_skip
    f_flops = f_tok * f_layer
    f_mem = f_tok * f_layer
    f_coll = f_tok * f_layer
    if k.matmul_precision == "int8":
        f_flops *= 0.70          # int8 MXU ~2x on the matmul share of a step
        f_mem *= 0.55            # weight/activation streaming halves
    if k.kv_keep_stride > 1:
        attn_share = 0.3
        f_flops *= (1 - attn_share) + attn_share / k.kv_keep_stride
        f_mem *= (1 - attn_share) + attn_share / k.kv_keep_stride
    if k.topk_override and cfg.moe is not None:
        moe_share = 0.6
        r = k.topk_override / cfg.moe.top_k
        f_flops *= (1 - moe_share) + moe_share * r
        f_coll *= (1 - moe_share) + moe_share * r
    if k.sync_period > 1:
        # the periodic pod sync is always full-precision (train/step.pod_sync
        # never re-rounds parameters), so compression contributes nothing here
        f_coll *= 1.0 / k.sync_period
    elif k.grad_compress == "int8":
        f_coll *= 0.3
    if k.kv_quant:
        f_mem *= 0.7
    if page_occupancy is not None and shape.kind == "decode":
        # decode HBM traffic priced by LIVE pages (the fused paged kernel
        # streams mapped pages, not slots x max_len rings): scale the KV
        # share of the memory term by occupancy. kv_share comes from the
        # compiled cell's cost_analysis (decode_kv_share) when the caller
        # provides it; 0.5 is the coarse long-context fallback.
        share = 0.5 if kv_share is None else min(max(kv_share, 0.0), 0.95)
        occ = min(max(page_occupancy, 0.0), 1.0)
        f_mem *= (1 - share) + share * occ
    comp2, mem2, coll2 = comp * f_flops, mem * f_mem, coll * f_coll
    t_prec = max(comp, mem, coll)
    t = max(comp2, mem2, coll2)
    # Pressure = per-step traffic normalized by the PRECISE bound: this is
    # the paper's mechanism — approximate variants issue less traffic into
    # the shared resource, so contention drops even while the job runs.
    pressure = ResourcePressure(
        hbm=mem2 / max(t_prec, 1e-30), ici=coll2 / max(t_prec, 1e-30),
        flops=comp2 / max(t_prec, 1e-30))
    return t / max(t_prec, 1e-30), pressure


def admission_cost(cfg: ModelConfig, mesh, chunk_len: int, kv_len: int, *,
                   use_kernel: Optional[bool] = None,
                   kv_quant: bool = False) -> dict:
    """Per-device price of one admission chunk's attention, laid out exactly
    as the traced cell will run it.

    Derives the ring layout from ``dist.sharding.prefill_plan`` — the same
    pure function the serving engine and the chunk cells dispatch on, so the
    priced shard count can never drift from the compiled one — and prices
    the per-device FLOPs/HBM bytes with ``roofline.admission_terms``. This
    is what the arbiter's admission-axis pressure attribution should read on
    a mesh: the ring divides the dominant O(chunk x context) attention work
    ``n_shards`` ways. Returns the terms dict plus ``n_shards`` and the
    plan/fallback ``reason`` ("" = ring dispatched)."""
    from repro import roofline
    from repro.dist.sharding import prefill_plan
    from repro.kernels import ops as kops
    n, reason = 1, "no mesh (single device)"
    if mesh is not None:
        if use_kernel is None:
            use_kernel = kops._on_tpu()
        if not use_kernel:
            reason = "kernel off: not on TPU"
        else:
            plan, reason = prefill_plan(cfg, mesh, chunk_len)
            if plan is not None:
                n = plan.n_shards
    out = roofline.admission_terms(cfg, chunk_len, kv_len, n_shards=n,
                                   kv_quant=kv_quant)
    out["n_shards"] = n
    out["reason"] = reason
    return out


# ------------------------------------------------------- pareto pruning --

def pareto_front(points: Sequence[Tuple[float, float]]) -> List[int]:
    """Indices of non-dominated (quality_loss, rel_time) points, sorted by
    increasing quality loss. Lower is better on both axes."""
    order = sorted(range(len(points)), key=lambda i: (points[i][0],
                                                      points[i][1]))
    out, best_t = [], float("inf")
    for i in order:
        if points[i][1] < best_t - 1e-12:
            out.append(i)
            best_t = points[i][1]
    return out


def explore(cfg: ModelConfig, shape, *, serving: bool = False,
            max_loss: float = 0.05, baseline_art: Optional[dict] = None,
            evaluate: Optional[Callable] = None,
            max_variants: int = 8,
            page_occupancy: Optional[float] = None,
            kv_share: Optional[float] = None) -> VariantTable:
    """Build the ordered VariantTable for one (arch, shape) colocation.

    ``evaluate(knobs) -> (rel_time, quality_loss, pressure)`` overrides the
    analytic backend (the measured path used by benchmarks).
    ``page_occupancy`` prices decode HBM by live pages (paged engines);
    ``kv_share`` anchors that pricing on the compiled decode cell's
    cost_analysis bytes (``decode_kv_share``).
    """
    cands = knob_grid(cfg, serving=serving)
    evaluated = []
    for k in cands:
        if evaluate is not None:
            rel_t, qloss, pressure = evaluate(k)
        else:
            rel_t, pressure = analytic_cost(cfg, shape, k, baseline_art,
                                            page_occupancy=page_occupancy,
                                            kv_share=kv_share)
            qloss = analytic_quality_loss(cfg, k)
        evaluated.append(Variant(k, rel_t, qloss, pressure))
    # threshold first (paper: discard variants with inaccuracy > 5%)
    ok = [v for v in evaluated if v.quality_loss <= max_loss]
    pts = [(v.quality_loss, v.rel_time) for v in ok]
    front = [ok[i] for i in pareto_front(pts)]
    # ordered precise -> most approximate (increasing quality loss)
    front.sort(key=lambda v: v.quality_loss)
    if not front or not front[0].knobs.is_precise():
        precise = next(v for v in evaluated if v.knobs.is_precise())
        front = [precise] + [v for v in front if not v.knobs.is_precise()]
    if len(front) > max_variants:
        idx = np.linspace(0, len(front) - 1, max_variants).round().astype(int)
        front = [front[int(i)] for i in sorted(set(idx))]
    return VariantTable(front)
