"""Pliant runtime algorithm — faithful implementation of paper Fig. 3.

State per colocation: the active variant index (0 = precise) and the number
of reclaimed resource quanta. The controller is deliberately agnostic to
WHAT a quantum is — the actuator decides: chip-groups for elastic batch
jobs (``PliantRuntime.reshard_fn``), page-pool quanta (``pool_pages``) for
the paged serving cache (``serve.pages.PagePool.set_reclaimed``). Per
decision interval:

* QoS violated, not at most-approximate  -> jump to MOST approximate variant
* QoS violated, already most-approximate -> reclaim one chip-group
* QoS met, slack > threshold, chips reclaimed -> return one chip-group
* QoS met, slack > threshold, no chips out    -> step one variant toward precise
* QoS met, low slack                          -> hold

The "jump to most approximate on violation, step back gradually" asymmetry is
the paper's anti-ping-pong hysteresis; the slack threshold (default 10%)
controls agility (§4.3, Fig. 9 sensitivity). Multi-tenant victim selection
lives in ``core/arbiter.py`` (round-robin baseline + interference-aware),
sharing this same per-tenant hysteresis.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field


class Action(enum.Enum):
    HOLD = "hold"
    SET_MOST_APPROX = "set_most_approx"
    STEP_PRECISE = "step_toward_precise"
    RECLAIM_CHIPS = "reclaim_chips"
    RETURN_CHIPS = "return_chips"


@dataclass
class ControllerConfig:
    slack_threshold: float = 0.10
    decision_interval_s: float = 1.0
    max_reclaim: int = 8            # reclaimable quanta (chip-groups / pages)
    history_limit: int = 2048       # decision-history ring size (PliantRuntime)


@dataclass
class AppState:
    n_variants: int
    variant: int = 0                # 0 = precise
    reclaimed: int = 0

    @property
    def most_approx(self) -> int:
        return self.n_variants - 1


@dataclass
class PliantController:
    """Single interactive service x single approximate application."""
    n_variants: int
    cfg: ControllerConfig = field(default_factory=ControllerConfig)
    state: AppState = field(init=False)

    def __post_init__(self):
        self.state = AppState(self.n_variants)

    def tick(self, qos_violated: bool, slack: float) -> Action:
        s = self.state
        if qos_violated:
            if s.variant < s.most_approx:
                # immediately jump to most approximate (Fig. 3)
                s.variant = s.most_approx
                return Action.SET_MOST_APPROX
            if s.reclaimed < self.cfg.max_reclaim:
                s.reclaimed += 1
                return Action.RECLAIM_CHIPS
            return Action.HOLD
        if slack > self.cfg.slack_threshold:
            if s.reclaimed > 0:
                s.reclaimed -= 1            # return chips before de-approximating
                return Action.RETURN_CHIPS
            if s.variant > 0:
                s.variant -= 1              # one step toward precise
                return Action.STEP_PRECISE
        return Action.HOLD


def headroom_burst(runtime, qos_guard: float) -> bool:
    """THE guard-band predicate: True when the attached runtime's monitor
    has a tail estimate comfortably inside the QoS target — p99 at most
    ``(1 - qos_guard) * target`` — i.e. there is measured headroom to spend
    on throughput. Both serving burst knobs consult it: the admission chunk
    budget (``ServeEngine._chunk_budget`` bursts prefill chunks) and the
    megastep width (``ServeEngine._megastep_budget`` fuses K decode steps
    per dispatch while admissions want interleaving). An abstaining monitor
    (below ``min_samples``) or no runtime at all is NO evidence of headroom
    — callers stay conservative."""
    if runtime is None:
        return False
    mon = runtime.monitor
    p99 = mon.p99()
    return (p99 is not None and mon.qos_target_s > 0
            and p99 <= (1.0 - qos_guard) * mon.qos_target_s)


def __getattr__(name):
    # RoundRobinArbiter moved to core/arbiter.py (one interface with the
    # InterferenceAwareArbiter); lazy re-export keeps old imports working
    # without a circular import in either direction.
    if name == "RoundRobinArbiter":
        from repro.core.arbiter import RoundRobinArbiter
        return RoundRobinArbiter
    raise AttributeError(name)
