"""Pliant runtime algorithm — faithful implementation of paper Fig. 3.

State per colocation: the active variant index (0 = precise) and the number
of reclaimed resource quanta. The controller is deliberately agnostic to
WHAT a quantum is — the actuator decides: chip-groups for elastic batch
jobs (``PliantRuntime.reshard_fn``), page-pool quanta (``pool_pages``) for
the paged serving cache (``serve.pages.PagePool.set_reclaimed``). Per
decision interval:

* QoS violated, not at most-approximate  -> jump to MOST approximate variant
* QoS violated, already most-approximate -> reclaim one chip-group
* QoS met, slack > threshold, chips reclaimed -> return one chip-group
* QoS met, slack > threshold, no chips out    -> step one variant toward precise
* QoS met, low slack                          -> hold

The "jump to most approximate on violation, step back gradually" asymmetry is
the paper's anti-ping-pong hysteresis; the slack threshold (default 10%)
controls agility (§4.3, Fig. 9 sensitivity).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional, Tuple


class Action(enum.Enum):
    HOLD = "hold"
    SET_MOST_APPROX = "set_most_approx"
    STEP_PRECISE = "step_toward_precise"
    RECLAIM_CHIPS = "reclaim_chips"
    RETURN_CHIPS = "return_chips"


@dataclass
class ControllerConfig:
    slack_threshold: float = 0.10
    decision_interval_s: float = 1.0
    max_reclaim: int = 8            # reclaimable quanta (chip-groups / pages)


@dataclass
class AppState:
    n_variants: int
    variant: int = 0                # 0 = precise
    reclaimed: int = 0

    @property
    def most_approx(self) -> int:
        return self.n_variants - 1


@dataclass
class PliantController:
    """Single interactive service x single approximate application."""
    n_variants: int
    cfg: ControllerConfig = field(default_factory=ControllerConfig)
    state: AppState = field(init=False)

    def __post_init__(self):
        self.state = AppState(self.n_variants)

    def tick(self, qos_violated: bool, slack: float) -> Action:
        s = self.state
        if qos_violated:
            if s.variant < s.most_approx:
                # immediately jump to most approximate (Fig. 3)
                s.variant = s.most_approx
                return Action.SET_MOST_APPROX
            if s.reclaimed < self.cfg.max_reclaim:
                s.reclaimed += 1
                return Action.RECLAIM_CHIPS
            return Action.HOLD
        if slack > self.cfg.slack_threshold:
            if s.reclaimed > 0:
                s.reclaimed -= 1            # return chips before de-approximating
                return Action.RETURN_CHIPS
            if s.variant > 0:
                s.variant -= 1              # one step toward precise
                return Action.STEP_PRECISE
        return Action.HOLD


@dataclass
class RoundRobinArbiter:
    """Multi-application colocation (paper §4.4): approximate one app at a
    time round-robin; only when ALL run most-approximate, reclaim chips one
    app and one chip-group at a time — no app penalized disproportionately."""
    n_variants_per_app: List[int]
    cfg: ControllerConfig = field(default_factory=ControllerConfig)
    start: int = 0                  # paper: first victim selected randomly
    states: List[AppState] = field(init=False)
    _cursor: int = field(init=False)

    def __post_init__(self):
        self.states = [AppState(n) for n in self.n_variants_per_app]
        self._cursor = self.start % len(self.states)

    def _next(self, pred) -> Optional[int]:
        n = len(self.states)
        for d in range(n):
            i = (self._cursor + d) % n
            if pred(self.states[i]):
                self._cursor = (i + 1) % n
                return i
        return None

    def tick(self, qos_violated: bool, slack: float
             ) -> Tuple[Action, Optional[int]]:
        if qos_violated:
            i = self._next(lambda s: s.variant < s.most_approx)
            if i is not None:
                self.states[i].variant = self.states[i].most_approx
                return Action.SET_MOST_APPROX, i
            i = self._next(lambda s: s.reclaimed < self.cfg.max_reclaim)
            if i is not None:
                self.states[i].reclaimed += 1
                return Action.RECLAIM_CHIPS, i
            return Action.HOLD, None
        if slack > self.cfg.slack_threshold:
            i = self._next(lambda s: s.reclaimed > 0)
            if i is not None:
                self.states[i].reclaimed -= 1
                return Action.RETURN_CHIPS, i
            i = self._next(lambda s: s.variant > 0)
            if i is not None:
                self.states[i].variant -= 1
                return Action.STEP_PRECISE, i
        return Action.HOLD, None
