"""AdamW with cosine schedule, global-norm clipping, fp32 moments.

Pure-jnp (no optax in this environment). Moment tensors inherit the param
shardings (passed through ``jax.tree.map`` structurally), so optimizer state
is FSDP/TP-sharded exactly like the weights.

``adamw_update`` takes an optional ``grad_reduce`` hook applied to the raw
gradients before clipping — the seam where ``repro.dist.collectives`` plugs
in the owned gradient-sync region (``grad_sync``): the explicit in-pod pmean
plus, when the knobs call for it, the int8-compressed cross-pod wire (the
``grad_compress`` knob) — without the optimizer knowing about meshes.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    m: object
    v: object


class OptConfig(NamedTuple):
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init_opt(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def lr_at(cfg: OptConfig, step):
    warm = cfg.lr * (step + 1) / max(cfg.warmup, 1)
    frac = jnp.clip((step - cfg.warmup)
                    / max(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = cfg.lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup, warm, cos).astype(jnp.float32)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt: OptState, params, cfg: OptConfig, *,
                 grad_reduce: Optional[Callable] = None):
    """Returns (new_params, new_opt, metrics).

    ``grad_reduce``: optional tree -> tree collective (e.g. compressed
    cross-pod mean) applied before clipping/moment updates.
    """
    if grad_reduce is not None:
        grads = grad_reduce(grads)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    step = opt.step + 1
    lr = lr_at(cfg, opt.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        update = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        p_new = (p.astype(jnp.float32)
                 - lr * (update + decay * p.astype(jnp.float32)))
        return p_new.astype(p.dtype), m_new, v_new

    gl, treedef = jax.tree.flatten(grads)
    res = [upd(g, m, v, p) for g, m, v, p in
           zip(gl, jax.tree.leaves(opt.m), jax.tree.leaves(opt.v),
               jax.tree.leaves(params))]
    new_params = treedef.unflatten([r[0] for r in res])
    new_m = treedef.unflatten([r[1] for r in res])
    new_v = treedef.unflatten([r[2] for r in res])
    return new_params, OptState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
