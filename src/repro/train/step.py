"""Train/serve step factories, parameterized by ``ApproxKnobs``.

``make_train_step(cfg, knobs, ...)`` returns a pure function suitable for
``jax.jit`` — one per approximate variant. The Pliant actuator (core/variants)
compiles each variant ONCE and switches which executable runs at a step
boundary: the TPU analogue of DynamoRIO's signal-triggered function swap.

Microbatching (gradient accumulation) runs as a ``lax.scan`` over static
micro-slices; gradients accumulate in fp32.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import api
from repro.approx.knobs import ApproxKnobs, PRECISE
from repro.train import optim


def _micro_split(batch, n_micro: int):
    def split(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(cfg: ModelConfig, knobs: ApproxKnobs = PRECISE, *,
                    opt_cfg: optim.OptConfig = optim.OptConfig(),
                    n_micro: int = 1, remat: str = "full",
                    ep_axis: Optional[str] = None, mesh=None,
                    donate: bool = True):
    """Returns step(params, opt, batch) -> (params, opt, metrics)."""
    loss_fn = api.loss_fn(cfg)

    def loss_of(params, micro_batch):
        loss, metrics = loss_fn(params, micro_batch, knobs=knobs,
                                ep_axis=ep_axis, mesh=mesh, remat=remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def step(params, opt, batch):
        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = _micro_split(batch, n_micro)

            def body(acc, mb):
                (l, m), g = grad_fn(params, mb)
                acc_g, acc_l = acc
                acc_g = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc_g, g)
                return (acc_g, acc_l + l), m

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            from repro import flags
            (gsum, lsum), metrics = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32)), micro,
                unroll=flags.unroll("micro"))
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        params, opt, opt_metrics = optim.adamw_update(grads, opt, params,
                                                      opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt, metrics

    return step


def make_serve_step(cfg: ModelConfig, knobs: ApproxKnobs = PRECISE, *,
                    ep_axis: Optional[str] = None, mesh=None):
    """Returns step(params, tokens, position, caches[, enc_out])
    -> (logits, new_caches). One new token against the KV/SSM caches."""
    decode = api.decode_fn(cfg)

    if cfg.family == "encdec":
        def step(params, tokens, position, caches, enc_out):
            return decode(params, tokens, position, caches, enc_out,
                          knobs=knobs)
        return step

    def step(params, tokens, position, caches):
        return decode(params, tokens, position, caches, knobs=knobs,
                      ep_axis=ep_axis, mesh=mesh)
    return step


def make_prefill_fn(cfg: ModelConfig, knobs: ApproxKnobs = PRECISE, *,
                    ep_axis: Optional[str] = None, mesh=None,
                    remat: str = "full"):
    """Full-sequence forward returning last-token logits (the prefill cell)."""
    from repro.models import encdec as encdec_mod
    from repro.models import lm as lm_mod

    def prefill(params, batch):
        if cfg.family == "encdec":
            enc_out = encdec_mod.encode(params, batch["frames"], cfg, knobs,
                                        remat=remat)
            h = encdec_mod.decode_hidden(params, batch["tokens"][:, :-1],
                                         enc_out, cfg, knobs, remat=remat)
        else:
            h, _ = lm_mod.forward_hidden(
                params, batch["tokens"][:, :-1], cfg, knobs,
                ep_axis=ep_axis, mesh=mesh, remat=remat,
                prefix_embeds=batch.get("prefix_embeds"))
        return lm_mod.logits_fn(params, h[:, -1], cfg)

    return prefill
