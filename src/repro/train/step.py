"""Train/serve step factories, parameterized by ``ApproxKnobs``.

``make_train_step(cfg, knobs, ...)`` returns a pure function suitable for
``jax.jit`` — one per approximate variant. The Pliant actuator (core/variants)
compiles each variant ONCE and switches which executable runs at a step
boundary: the TPU analogue of DynamoRIO's signal-triggered function swap.

Microbatching (gradient accumulation) runs as a ``lax.scan`` over static
micro-slices; gradients accumulate in fp32.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import api
from repro.approx.knobs import ApproxKnobs, PRECISE
from repro.dist import collectives
from repro.train import optim


def grad_reduce_for(knobs: ApproxKnobs, mesh, pspecs=None):
    """The owned gradient-sync region an (knobs, mesh) pair calls for.

    Returns a tree -> tree callable wrapping ONE shard_map region
    (``collectives.grad_sync``), or None when there is nothing to own:

    * single device / mesh without data or pod axes -> None.
    * ``data`` axis present -> explicit in-pod pmean over ``data`` (idempotent
      on GSPMD's implicit reduction, but now trace-visible and priceable).
    * ``pod`` axis present and ``sync_period == 1`` -> the cross-pod mean
      rides in the same region, int8-wire when ``grad_compress == "int8"``.
    * ``sync_period > 1`` -> the pod collective is ELIDED AT TRACE TIME: the
      compiled step carries zero pod wire bytes; the launcher runs
      ``pod_sync`` every k steps instead (local-SGD style).

    The returned callable exposes ``.pod_wire`` / ``.compress`` for
    introspection (tests, dry-run accounting).
    """
    shape = getattr(mesh, "shape", {}) if mesh is not None else {}
    if "data" not in shape and "pod" not in shape:
        return None
    pod_wire = "pod" in shape and knobs.sync_period == 1
    compress = knobs.grad_compress == "int8"

    def reduce_fn(g):
        return collectives.grad_sync(g, mesh, pod_wire=pod_wire,
                                     compress=compress, pspecs=pspecs)
    reduce_fn.pod_wire = pod_wire
    reduce_fn.compress = compress
    return reduce_fn


_POD_SYNC_CACHE = {}


def pod_sync(params, mesh, pspecs=None):
    """Periodic pod-level param sync (the ``sync_period`` knob). No-op
    without a pod axis, so launchers call it unconditionally every k steps.

    Always full-precision wire: int8-compressing the *parameters* would
    re-round model state to 8-bit resolution every sync (unlike gradients,
    where the quantization noise is consumed once and scaled by lr) —
    ``grad_compress`` only shapes the per-step gradient path. The jitted sync
    is cached per (mesh, tree structure) so the train hot loop never
    re-traces it.
    """
    if mesh is None or "pod" not in getattr(mesh, "shape", {}):
        return params
    if pspecs is not None:      # rare, launcher-specific: don't cache
        return collectives.pod_sync_params(params, mesh, pspecs=pspecs)
    key = (mesh, jax.tree.structure(params))
    fn = _POD_SYNC_CACHE.get(key)
    if fn is None:
        fn = jax.jit(lambda p: collectives.pod_sync_params(p, mesh))
        _POD_SYNC_CACHE[key] = fn
    return fn(params)


def _micro_split(batch, n_micro: int):
    def split(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(cfg: ModelConfig, knobs: ApproxKnobs = PRECISE, *,
                    opt_cfg: optim.OptConfig = optim.OptConfig(),
                    n_micro: int = 1, remat: str = "full",
                    ep_axis: Optional[str] = None, mesh=None,
                    donate: bool = True, param_pspecs=None):
    """Returns step(params, opt, batch) -> (params, opt, metrics)."""
    loss_fn = api.loss_fn(cfg)
    grad_reduce = grad_reduce_for(knobs, mesh, param_pspecs)

    def loss_of(params, micro_batch):
        loss, metrics = loss_fn(params, micro_batch, knobs=knobs,
                                ep_axis=ep_axis, mesh=mesh, remat=remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def step(params, opt, batch):
        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = _micro_split(batch, n_micro)

            def body(acc, mb):
                (l, m), g = grad_fn(params, mb)
                acc_g, acc_l = acc
                acc_g = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc_g, g)
                return (acc_g, acc_l + l), m

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            from repro import flags
            (gsum, lsum), metrics = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32)), micro,
                unroll=flags.unroll("micro"))
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        params, opt, opt_metrics = optim.adamw_update(grads, opt, params,
                                                      opt_cfg,
                                                      grad_reduce=grad_reduce)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt, metrics

    return step


def make_serve_step(cfg: ModelConfig, knobs: ApproxKnobs = PRECISE, *,
                    ep_axis: Optional[str] = None, mesh=None):
    """Returns step(params, tokens, position, caches[, enc_out])
    -> (logits, new_caches). One new token against the KV/SSM caches."""
    decode = api.decode_fn(cfg)

    if cfg.family == "encdec":
        def step(params, tokens, position, caches, enc_out):
            return decode(params, tokens, position, caches, enc_out,
                          knobs=knobs)
        return step

    def step(params, tokens, position, caches):
        return decode(params, tokens, position, caches, knobs=knobs,
                      ep_axis=ep_axis, mesh=mesh)
    return step


def make_paged_serve_step(cfg: ModelConfig, knobs: ApproxKnobs = PRECISE, *,
                          ep_axis: Optional[str] = None, mesh=None,
                          use_kernel: Optional[bool] = None,
                          dynamic_scatter: bool = False,
                          sample_greedy: bool = False,
                          interpret: bool = False):
    """Returns step(params, tokens, position, active, caches)
    -> (logits_or_tokens, new_caches) — the paged engine's decode cell.

    ``active`` (B,) bool masks per-slot cache writes so decode steps can
    interleave with a background admission: the admitting slot's mapped
    pages / SSM rows must not receive garbage from its dead batch row.
    ``use_kernel`` overrides the fused-kernel dispatch; under a ``mesh``
    the kernel runs shard_map'd over the slot-affinity pool layout when
    ``dist.sharding.paged_decode_plan`` allows, else the GSPMD gather path
    (with a logged warning). ``interpret`` runs the sharded kernel in
    Pallas interpret mode (simulated-device CI).
    ``dynamic_scatter`` selects the O(1)-per-entry dynamic cache write
    (single-device pools only — the sharded kernel path does its own
    dynamic write inside the shard; see
    ``attention.paged_decode_attention``).
    ``sample_greedy`` fuses argmax into the executable and returns (B,)
    int32 tokens instead of (B, V) logits: the greedy engine then moves
    B*4 bytes per step off-device instead of the full logits matrix."""
    decode = api.decode_fn(cfg)
    assert cfg.family != "encdec", "paged serving: decoder-only path"

    def step(params, tokens, position, active, caches):
        logits, caches = decode(params, tokens, position, caches, knobs=knobs,
                                ep_axis=ep_axis, mesh=mesh, active=active,
                                use_kernel=use_kernel,
                                dyn_scatter=dynamic_scatter,
                                interpret=interpret)
        if sample_greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches
        return logits, caches
    return step


def make_paged_megastep(cfg: ModelConfig, knobs: ApproxKnobs = PRECISE, *,
                        k: int, temperature: float = 0.0, seed: int = 0,
                        eos_id: int = -1, ep_axis: Optional[str] = None,
                        mesh=None, use_kernel: Optional[bool] = None,
                        dynamic_scatter: bool = False,
                        interpret: bool = False):
    """Returns step(params, cur, pos, alive, uids, draws, budget, caches)
    -> (toks (B,K), cur, pos, alive, draws, budget, caches) — K fused
    decode steps with on-device sampling and stop masking in ONE
    executable (``lm.decode_megastep``).

    The caches argument sits at position 7 so the engine can jit with
    ``donate_argnums=(7,)`` and update the paged pool + SSM state in
    place. All the per-row carries (cur/pos/alive/draws/budget) round-trip
    through the executable so the engine can chain megasteps device-side
    without a host sync between them."""
    from repro.models import lm as lm_mod
    assert cfg.family != "encdec", "megastep: decoder-only path"

    def step(params, cur, pos, alive, uids, draws, budget, caches):
        return lm_mod.decode_megastep(
            params, cur, pos, alive, uids, draws, budget, caches, cfg, knobs,
            k=k, temperature=temperature, seed=seed, eos_id=eos_id,
            ep_axis=ep_axis, mesh=mesh, use_kernel=use_kernel,
            dyn_scatter=dynamic_scatter, interpret=interpret)
    return step


def make_admission_step(cfg: ModelConfig, knobs: ApproxKnobs = PRECISE, *,
                        mesh=None, use_kernel: Optional[bool] = None,
                        interpret: bool = False):
    """Returns step(params, tokens, start, caches) -> (logits, caches).

    One prompt chunk against existing decode caches — the serving engine's
    chunked-prefill admission cell. ``start`` is traced, so ONE executable
    per (variant, chunk length) serves every chunk of a streaming prompt.
    Under a ``mesh`` the chunk attention dispatches on
    ``dist.sharding.prefill_plan`` (ring sequence parallelism);
    ``use_kernel``/``interpret`` mirror ``make_paged_serve_step``."""
    from repro.serve import prefill as prefill_mod

    def step(params, tokens, start, caches):
        return prefill_mod.prefill_chunk(params, tokens, start, caches, cfg,
                                         knobs=knobs, mesh=mesh,
                                         use_kernel=use_kernel,
                                         interpret=interpret)
    return step


def make_paged_admission_step(cfg: ModelConfig, knobs: ApproxKnobs = PRECISE,
                              *, dynamic_scatter: bool = False, mesh=None,
                              use_kernel: Optional[bool] = None,
                              interpret: bool = False):
    """Returns step(params, tokens, start, caches, slot) -> (logits, caches).

    The paged engine's admission cell: one prompt chunk written straight
    into the batched page-pool caches at ``slot``'s block-table row. Both
    ``start`` and ``slot`` are traced — ONE executable per (variant, chunk
    length) serves every chunk of every slot. ``dynamic_scatter`` as in
    ``make_paged_serve_step``; ``mesh``/``use_kernel``/``interpret`` select
    the ring-sequence-parallel chunk attention when the prefill plan
    applies."""
    from repro.serve import prefill as prefill_mod

    def step(params, tokens, start, caches, slot):
        return prefill_mod.paged_prefill_chunk(params, tokens, start, caches,
                                               slot, cfg, knobs=knobs,
                                               dyn_scatter=dynamic_scatter,
                                               mesh=mesh,
                                               use_kernel=use_kernel,
                                               interpret=interpret)
    return step


def make_prefill_fn(cfg: ModelConfig, knobs: ApproxKnobs = PRECISE, *,
                    ep_axis: Optional[str] = None, mesh=None,
                    remat: str = "full"):
    """Full-sequence forward returning last-token logits (the prefill cell)."""
    from repro.models import encdec as encdec_mod
    from repro.models import lm as lm_mod

    def prefill(params, batch):
        if cfg.family == "encdec":
            enc_out = encdec_mod.encode(params, batch["frames"], cfg, knobs,
                                        remat=remat)
            h = encdec_mod.decode_hidden(params, batch["tokens"][:, :-1],
                                         enc_out, cfg, knobs, remat=remat)
        else:
            h, _ = lm_mod.forward_hidden(
                params, batch["tokens"][:, :-1], cfg, knobs,
                ep_axis=ep_axis, mesh=mesh, remat=remat,
                prefix_embeds=batch.get("prefix_embeds"))
        return lm_mod.logits_fn(params, h[:, -1], cfg)

    return prefill
