"""Architecture registry: ``--arch <id>`` resolves through ``get_config``."""
from __future__ import annotations

from repro.configs.base import (ATTN, LOCAL_ATTN, MAMBA, SHARED_ATTN, SHAPES,
                                ModelConfig, MoEConfig, ShapeConfig, SSMConfig,
                                shape_applicable, smoke_config)

from repro.configs.zamba2_2p7b import CONFIG as _zamba2
from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.mistral_large_123b import CONFIG as _mistral
from repro.configs.phi4_mini_3p8b import CONFIG as _phi4
from repro.configs.gemma2_27b import CONFIG as _gemma2
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.paligemma_3b import CONFIG as _paligemma
from repro.configs.mamba2_780m import CONFIG as _mamba2
from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot

ARCHS = {
    c.name: c for c in [
        _zamba2, _gemma3, _mistral, _phi4, _gemma2,
        _whisper, _paligemma, _mamba2, _olmoe, _moonshot,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return smoke_config(ARCHS[name[: -len("-smoke")]])
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_cells():
    """Every (arch, shape) pair with its applicability verdict."""
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            ok, reason = shape_applicable(arch, shape)
            yield arch, shape, ok, reason
