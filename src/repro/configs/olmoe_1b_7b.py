"""olmoe-1b-7b [moe]: 64 experts, top-8.

16L d_model=2048 16H (GQA kv=16 = MHA) d_ff=1024 (per expert) vocab=50304,
MoE 64e top-8 [arXiv:2409.02060; hf].
"""
from repro.configs.base import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    pattern=(ATTN,),
    moe=MoEConfig(n_experts=64, top_k=8),
    rope_theta=10_000.0,
    sub_quadratic=False,
)
