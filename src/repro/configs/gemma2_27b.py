"""gemma2-27b [dense]: local+global alternating attention, logit softcaps.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000
[arXiv:2408.00118; hf]. head_dim=128 (q_dim 4096 != d_model), window 4096,
attn softcap 50.0, final logit softcap 30.0.
"""
from repro.configs.base import ATTN, LOCAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    pattern=(LOCAL_ATTN, ATTN),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    rope_theta=10_000.0,
    sub_quadratic=True,   # alternating sliding-window layers
)
