"""paligemma-3b [vlm]: SigLIP stub + gemma backbone (MQA).

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216 [arXiv:2407.07726; hf].
head_dim=256 (gemma-2b convention). The SigLIP tower is a STUB per the brief:
``input_specs()`` provides 256 precomputed patch embeddings prepended to the
text sequence.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    pattern=(ATTN,),
    n_prefix_tokens=256,
    rope_theta=10_000.0,
    sub_quadratic=False,
)
