"""zamba2-2.7b [hybrid]: Mamba2 blocks + shared-weight attention block.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]. Pattern period 6: five Mamba2 blocks then one
invocation of the single shared attention+MLP block (weights reused across
all 9 invocations, zamba2-style).
"""
from repro.configs.base import MAMBA, SHARED_ATTN, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    pattern=(MAMBA, MAMBA, MAMBA, MAMBA, MAMBA, SHARED_ATTN),
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64),
    rope_theta=10_000.0,
    sub_quadratic=True,   # SSM backbone; attention only at 1/6 of positions
)
