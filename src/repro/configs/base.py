"""Config dataclasses for architectures, shapes, and meshes.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input shapes are ``ShapeConfig`` instances in ``SHAPES``. Reduced
("smoke") configs reuse the same family logic at toy scale so every arch can
run a real forward/train step on CPU.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# Layer kinds used to build the per-layer pattern of a model. The decoder
# stack scans over *groups* of layers; a group is one period of the pattern.
ATTN = "attn"            # full (global) self-attention
LOCAL_ATTN = "local"     # sliding-window self-attention
MAMBA = "mamba"          # Mamba2 SSD mixer
SHARED_ATTN = "shared"   # zamba2-style shared-weight attention block


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    # capacity factor for dense dispatch (tokens per expert per batch*seq)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 128  # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # layer pattern: tuple of layer kinds, one *period*; tiled to n_layers.
    pattern: Tuple[str, ...] = (ATTN,)
    window: int = 0                  # sliding window size for LOCAL_ATTN
    attn_softcap: float = 0.0        # gemma2-style attention logit softcap
    final_softcap: float = 0.0       # gemma2-style final logit softcap
    rope_theta: float = 10_000.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # enc-dec (whisper): encoder layers share d_model/heads/d_ff
    n_encoder_layers: int = 0
    encoder_seq: int = 0             # fixed stub frame-embedding length
    # vlm (paligemma): number of prefix patch-embedding tokens (stub frontend)
    n_prefix_tokens: int = 0
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # does full attention appear in the pattern? (long_500k gating)
    sub_quadratic: bool = False
    max_position: int = 1 << 20

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def n_groups(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern period {len(self.pattern)}")
        return self.n_layers // len(self.pattern)

    def kinds(self) -> Tuple[str, ...]:
        """Full per-layer kind sequence (pattern tiled to n_layers)."""
        return tuple(self.pattern[i % len(self.pattern)]
                     for i in range(self.n_layers))

    def param_count(self) -> int:
        """Analytic parameter count (matches init exactly; asserted in tests)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = 0
        n += self.vocab_size * d                       # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d
        per_kind = {}
        attn_p = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        mlp_p = 3 * d * self.d_ff                      # SwiGLU: gate/up/down
        if self.moe is not None:
            mlp_p = self.moe.n_experts * 3 * d * self.d_ff + d * self.moe.n_experts
        per_kind[ATTN] = attn_p + mlp_p + 2 * d
        per_kind[LOCAL_ATTN] = per_kind[ATTN]
        if self.ssm is not None:
            di = self.ssm.expand * d
            nh = di // self.ssm.head_dim
            # in_proj: [d, 2*di + 2*d_state + nh] (z, x, B, C, dt) with n_groups=1
            in_p = d * (2 * di + 2 * self.ssm.d_state + nh)
            conv_p = (di + 2 * self.ssm.d_state) * self.ssm.conv_width
            extra = nh * 3                             # A_log, D, dt_bias
            out_p = di * d + di                        # out_proj + gate norm
            # Mamba blocks carry no MLP (mamba2/zamba2 style); d_ff belongs to
            # attention / shared blocks only.
            per_kind[MAMBA] = in_p + conv_p + extra + out_p + d
        shared = 0
        if SHARED_ATTN in self.pattern:
            shared = attn_p + 3 * d * self.d_ff + 2 * d
        for k in self.kinds():
            if k == SHARED_ATTN:
                continue                               # counted once below
            n += per_kind[k]
        n += shared
        n += d                                         # final norm
        if self.n_encoder_layers:
            # encoder: self-attn + MLP blocks; decoder layers add cross-attn
            enc = self.n_encoder_layers * (attn_p + mlp_p + 2 * d) + d
            cross = self.n_layers * (attn_p + d)
            n += enc + cross
        if self.n_prefix_tokens:
            n += 0                                     # stub frontend: no params
        return n


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs; (False, reason) for documented skips."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k decode cache is quadratic-cost to build; skipped per brief (DESIGN.md §5)"
    if shape.name == "long_500k" and cfg.family == "encdec":
        return False, "enc-dec decoder max context << 500k (DESIGN.md §5)"
    return True, ""


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config: small dims, few layers/experts, tiny vocab."""
    if cfg.n_kv_heads <= 1:
        smoke_kv = 1                       # preserve MQA
    elif cfg.n_kv_heads < cfg.n_heads:
        smoke_kv = 2                       # preserve GQA
    else:
        smoke_kv = 4                       # preserve MHA
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=len(cfg.pattern) * min(2, cfg.n_groups),
        d_model=64,
        n_heads=4,
        n_kv_heads=smoke_kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        window=min(cfg.window, 32) if cfg.window else 0,
        max_position=4096,
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(n_experts=8, top_k=min(cfg.moe.top_k, 2),
                              capacity_factor=cfg.moe.capacity_factor)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, expand=2, head_dim=16, chunk=16)
    if cfg.n_encoder_layers:
        kw["n_encoder_layers"] = 2
        kw["encoder_seq"] = 16
    if cfg.n_prefix_tokens:
        kw["n_prefix_tokens"] = 4
    return replace(cfg, **kw)
