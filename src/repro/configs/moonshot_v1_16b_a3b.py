"""moonshot-v1-16b-a3b [moe]: kimi/moonlight, 64 experts top-6.

48L d_model=2048 16H (GQA kv=16 = MHA) d_ff=1408 (per expert) vocab=163840,
MoE 64e top-6 [hf:moonshotai/Moonlight-16B-A3B; hf].
"""
from repro.configs.base import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    pattern=(ATTN,),
    moe=MoEConfig(n_experts=64, top_k=6),
    rope_theta=10_000.0,
    sub_quadratic=False,
)
