"""whisper-large-v3 [audio]: encoder-decoder backbone; conv frontend is a stub.

32L d_model=1280 20H (GQA kv=20 = MHA) d_ff=5120 vocab=51866
[arXiv:2212.04356; unverified]. Per the brief the modality frontend is a stub:
``input_specs()`` provides precomputed 1500-frame embeddings. Assigned shapes
apply to the decoder sequence (DESIGN.md §5). Adaptation note: MLPs are SwiGLU
(framework-uniform) rather than whisper's 2-matrix GELU.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,             # decoder layers
    n_encoder_layers=32,
    encoder_seq=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    pattern=(ATTN,),
    rope_theta=10_000.0,
    sub_quadratic=False,
)
