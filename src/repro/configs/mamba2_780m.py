"""mamba2-780m [ssm]: attention-free SSD (state-space duality).

48L d_model=1536 (attn-free) d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified]. Pure Mamba2 blocks, no MLP (d_ff=0).
"""
from repro.configs.base import MAMBA, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,            # unused: attention-free
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    pattern=(MAMBA,),
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64),
    sub_quadratic=True,
)
