"""Slot-scatter helpers for chunked-prefill admission.

``insert_request`` splices a prefilled single-request cache into the engine's
batched KV/Mamba caches; ``convert_caches`` re-encodes the KV rings when a
variant hot-swap crosses the ``kv_quant`` boundary. Both are pure pytree
functions (jit-friendly; ``slot`` may be traced).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import (KVCache, KV_SCALE, PagedKVCache,
                                    dequantize_kv, quantize_kv)
from repro.models.mamba2 import MambaCache


def insert_request(batched, single, slot):
    """Scatter a prefilled 1-request cache tree into batch row ``slot``.

    Both trees are in ``lm.init_caches`` layout (leaves stacked over layer
    groups, batch at axis 1). Attention rings are rotated so the request's
    entries occupy exactly the slots a token-by-token warmup ending at the
    engine's current cursor would have filled — subsequent decode writes land
    after them and never clobber a live prompt entry until the ring genuinely
    wraps. The batched cursor (global, shared by all slots) is kept.
    """
    def one(bc, sc):
        if isinstance(bc, KVCache):
            W = bc.k.shape[2]
            shift = (bc.cursor[0] - sc.cursor[0]) % W
            roll = lambda x: jnp.roll(x, shift, axis=2)
            return KVCache(
                k=bc.k.at[:, slot].set(roll(sc.k)[:, 0]),
                v=bc.v.at[:, slot].set(roll(sc.v)[:, 0]),
                pos=bc.pos.at[:, slot].set(roll(sc.pos)[:, 0]),
                cursor=bc.cursor)
        assert isinstance(bc, MambaCache), type(bc)
        return MambaCache(*(b.at[:, slot].set(s[:, 0])
                            for b, s in zip(bc, sc)))

    return tuple(one(b, s) for b, s in zip(batched, single))


def convert_caches(caches, kv_quant: bool, dtype=jnp.float32):
    """Re-encode KV rings across a ``kv_quant`` hot-swap boundary.

    int8 -> ``dtype`` when leaving a quantized variant, ``dtype`` -> int8 when
    entering one (shared static ``KV_SCALE``, the same rounding decode and
    chunked prefill apply). Positions, cursors, block tables, and Mamba state
    carry over — decode continues mid-request across the swap. Paged pools
    convert every physical page in place (shared prefix pages included, so
    all sharers stay consistent); the engine flushes the knob-tagged prefix
    index on a swap since re-encoded pages match no registered tag.

    The conversion is elementwise per physical page, so under a slot-affinity
    sharded pool (DESIGN.md §13) it is layout-preserving: GSPMD keeps every
    page on its owning device and a hot-swap never migrates pages across
    shards — no re-planning needed around a variant switch.
    """
    q = quantize_kv
    dq = lambda x: dequantize_kv(x, dtype)

    def one(c):
        if isinstance(c, KVCache):
            if kv_quant and c.k.dtype != jnp.int8:
                return c._replace(k=q(c.k), v=q(c.v))
            if not kv_quant and c.k.dtype == jnp.int8:
                return c._replace(k=dq(c.k), v=dq(c.v))
            return c
        if isinstance(c, PagedKVCache):
            if kv_quant and c.kp.dtype != jnp.int8:
                return c._replace(kp=q(c.kp), vp=q(c.vp))
            if not kv_quant and c.kp.dtype == jnp.int8:
                return c._replace(kp=dq(c.kp), vp=dq(c.vp))
            return c
        return c

    return tuple(one(c) for c in caches)
