"""Device-resident paged cache pool: host-side allocator, block tables,
copy-on-write prefix sharing, and the Pliant-reclaimable page budget.

The pool replaces the dense per-slot rings of the serving engine: KV entries
live in a shared physical page pool (``models.attention.PagedKVCache``) and
each slot maps logical pages (position // page_size) to physical pages
through a block table. This module owns everything HOST-side about that
mapping — allocation never happens inside a jitted step:

* **Free-list allocator.** Physical page 0 is the reserved null/trash page
  (unmapped block-table entries point at it and are masked out of attention;
  inactive decode rows scatter into it harmlessly). Pages are refcounted:
  a page is owned by every slot whose block table maps it PLUS the prefix
  index entries that pin it, and returns to the free list at refcount 0.

* **Prefix index (copy-on-write sharing).** Admission registers the longest
  full-page prompt prefix under a key of (knobs, token tuple); a later
  request with the same prefix maps those pages directly into its block
  table (refcount bump — no copy, no recompute) and skips the corresponding
  prefill chunks entirely. Shared pages are immutable by construction: only
  FULL prompt pages are ever shared, lookups cap at ``len(prompt) - 1``
  tokens so at least one token always re-prefills into a private tail page,
  and decode writes only ever land in private pages — so "copy-on-write"
  never needs a write fault, the tail is simply never shared. For archs with
  Mamba layers the entry also carries the host snapshot of the per-slot SSM
  state at the prefix boundary, restored on a hit.

* **Grouped / speculative allocation.** ``admit(..., reserve_tokens=n)``
  allocates the prompt's pages AND the request's projected decode pages in
  ONE all-or-nothing free-list transaction, so the continuous-batching hot
  loop never touches the allocator between decode steps (``_push_blocks``
  churn drops to admission boundaries). When the full group does not fit
  the pool falls back to prompt-only (``ensure_decode_page`` then grows
  lazily, as before). ``replenish`` is the watermark-based background
  reservation: called by the engine BETWEEN steps, it evicts LRU prefix
  entries whenever allocatable headroom drops below the low watermark —
  moving eviction churn off the admission path.

* **Slot-affinity sharding (multi-device pools).** With ``n_shards`` > 1 the
  physical page range splits into contiguous per-device shards (shard ``s``
  owns pages ``[s * shard_pages, (s+1) * shard_pages)``, whose first page is
  that shard's reserved null page) and every slot is pinned to the shard
  ``slot * n_shards // batch_slots`` — the SAME contiguous split GSPMD uses
  when the pool's page dim and the block table's slot dim are sharded over
  the batch mesh axes. All of a slot's pages (private, prefix-shared, and
  speculative alike) come from its own shard, so inside ``shard_map`` each
  device resolves its slots' block tables entirely against local pages: the
  fused decode kernel runs per-shard with zero collectives, and the
  dynamic-index cache write becomes legal under the mesh. The prefix index
  is shard-local too (keys are shard-tagged): sharing never migrates a page
  across devices. ``n_shards=1`` reduces exactly to the layout above.

* **Reclaimable budget (the ``pool_pages`` Pliant knob).** ``set_reclaimed``
  shrinks the allocatable-page limit in quanta; shrinking evicts prefix
  index entries (LRU) — the approximation-tolerant pages, in Pliant terms —
  and blocks NEW admissions while over budget, but never touches pages owned
  by live requests (growth for an in-flight decode is always honored), so a
  shrink/regrow round-trip cannot corrupt an in-flight request. The serve
  engine wires this to ``PliantRuntime`` RECLAIM/RETURN actions.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class PageSpec:
    """Static shape of a paged cache pool (the engine's cache-spec)."""
    page_size: int       # tokens per page
    n_pages: int         # physical pages, INCLUDING the reserved null pages
    max_pages: int       # logical pages per slot (ceil(max_len / page_size))
    n_shards: int = 1    # slot-affinity device shards (1 = unsharded pool)

    @property
    def usable(self) -> int:
        return self.n_pages - self.n_shards

    @property
    def shard_pages(self) -> int:
        """Physical pages per shard (the first one is that shard's null)."""
        return self.n_pages // self.n_shards


def spec_for(batch_slots: int, max_len: int, page_size: int = 8,
             n_pages: int = 0, n_shards: int = 1) -> PageSpec:
    """Default pool sizing: every slot can hold a full ``max_len`` sequence,
    plus one sequence's worth of slack per shard for the prefix cache.
    ``n_pages`` is rounded up to a multiple of lcm(8, n_shards) so the
    physical page dim stays shardable (``dist.sharding.cache_shardings``)
    AND splits evenly into the slot-affinity shards."""
    import math
    max_pages = -(-max_len // page_size)
    if n_pages <= 0:
        n_pages = n_shards + (batch_slots + n_shards) * max_pages
    mult = 8 * n_shards // math.gcd(8, n_shards)
    n_pages = -(-n_pages // mult) * mult
    return PageSpec(page_size, n_pages, max_pages, n_shards)


class CacheStore:
    """Minimal per-slot cache-residency protocol the engine drives.

    ``PagePool`` implements it for paged attention state; ``MambaSlotStore``
    for the dense per-slot SSM state (which has nothing to allocate — one
    row per slot, always resident — but sits behind the same surface so the
    engine frees/queries every cache kind uniformly)."""

    def free_slot(self, slot: int) -> bool:
        """Release slot-owned residency. Returns True if device-visible
        mapping state changed (the engine must re-push block tables)."""
        raise NotImplementedError

    def occupancy(self) -> float:
        raise NotImplementedError


class MambaSlotStore(CacheStore):
    """Per-slot dense state store: state travels with the slot row, so
    freeing is a no-op (the next admission overwrites it)."""

    def free_slot(self, slot: int) -> bool:
        return False

    def occupancy(self) -> float:
        return 1.0


@dataclass
class PrefixEntry:
    pages: Tuple[int, ...]       # physical pages of the shared prefix
    n_tokens: int                # page-aligned prefix length
    mamba: Any = None            # host SSM-state snapshot at the boundary
    last_use: int = 0
    hits: int = 0


@dataclass
class AdmitPlan:
    shared_tokens: int           # prompt tokens whose prefill is skipped
    entry: Optional[PrefixEntry]
    register: List[int]          # page boundaries to snapshot+register
    reserved_pages: int = 0      # speculative decode pages mapped up front


class PagePool(CacheStore):
    def __init__(self, spec: PageSpec, batch_slots: int,
                 reclaim_quantum: int = 0, max_register_pages: int = 64):
        self.spec = spec
        self.batch_slots = batch_slots
        # bound on registered boundaries per prompt: caps index growth, the
        # per-entry pages tuples, and (hybrid archs) the per-boundary SSM
        # snapshots an admission pauses for — prompts share at most this
        # many leading pages (stats["register_capped"] counts the overflow)
        self.max_register_pages = max_register_pages
        assert spec.n_pages % spec.n_shards == 0, spec
        assert batch_slots % spec.n_shards == 0, \
            (batch_slots, spec.n_shards, "slot affinity needs an even split")
        # per-shard free lists: page s*shard_pages is shard s's reserved null
        self._free: List[collections.deque] = [
            collections.deque(range(s * spec.shard_pages + 1,
                                    (s + 1) * spec.shard_pages))
            for s in range(spec.n_shards)]
        self.ref = np.zeros(spec.n_pages, np.int32)
        self.blocks = np.zeros((batch_slots, spec.max_pages), np.int32)
        self.slot_pages: List[List[int]] = [[] for _ in range(batch_slots)]
        self.index: Dict[tuple, PrefixEntry] = {}
        self.quantum = reclaim_quantum or spec.max_pages
        self.reclaimed = 0
        # capacity cut (CapacityEvent QUOTA_CUT quanta): an EXTERNAL floor on
        # the budget, deliberately separate from ``reclaimed`` — the Pliant
        # arbiter's ledger must track only its own actuations, or a quota
        # grab would desync it from the quanta it believes it can return
        self.capacity_cut = 0
        self.scrub_pending: List[int] = []   # fully-freed pages: stale device
        self._clock = 0                      # ppos must be cleared before reuse
        self.stats: Dict[str, Any] = dict(
            allocs=0, frees=0, prefix_hits=0, prefix_misses=0,
            prefix_registered=0, prefix_evicted=0, tokens_skipped=0,
            blocked_admissions=0, reclaim_events=0, over_limit_allocs=0,
            register_capped=0, peak_used=0, window_freed=0,
            grouped_admissions=0, grouped_pages=0, grouped_fallbacks=0,
            replenish_evictions=0, capacity_cut_events=0,
            elastic_migrations=0, elastic_prefix_evicted=0)

    # --------------------------------------------------------- accounting --

    @property
    def free(self) -> List[int]:
        """Flattened free list across shards (read-only audit view)."""
        return [p for dq in self._free for p in dq]

    def slot_shard(self, slot: int) -> int:
        """The device shard that owns ``slot``'s pages: the contiguous split
        GSPMD applies when the block table's slot dim is batch-sharded."""
        return slot * self.spec.n_shards // self.batch_slots

    def page_shard(self, pid: int) -> int:
        return pid // self.spec.shard_pages

    @property
    def used(self) -> int:
        return self.spec.usable - sum(len(dq) for dq in self._free)

    @property
    def limit(self) -> int:
        return max(self.spec.usable
                   - (self.reclaimed + self.capacity_cut) * self.quantum, 0)

    @property
    def max_quanta(self) -> int:
        """Reclaim budget exposed to the controller: the slack above one
        live sequence per slot, in quanta (>= 1 so the knob always exists)."""
        slack = self.spec.usable - self.batch_slots * self.spec.max_pages
        return max(1, slack // self.quantum)

    def occupancy(self) -> float:
        return self.used / max(self.spec.usable, 1)

    def live_slot_pages(self) -> int:
        return sum(len(p) for p in self.slot_pages)

    # --------------------------------------------------------- allocation --

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _alloc(self, shard: int = 0, *, for_live: bool = False
               ) -> Optional[int]:
        """Pop a free physical page of ``shard`` (refcount 1). Evicts LRU
        prefix entries under pressure — any shard's entries relieve the
        global reclaim budget, but only ``shard``'s entries can refill its
        free list (pages never migrate). ``for_live`` allocations (decode
        growth of an in-flight request) may exceed the reclaim limit —
        reclamation must never corrupt a live request."""
        if not for_live:
            while self.used >= self.limit and self.index:
                self._evict_lru()
            if self.used >= self.limit:
                return None
        while not self._free[shard]:
            if not self._evict_lru(shard):
                break
        if not self._free[shard]:
            return None
        if self.used >= self.limit:
            self.stats["over_limit_allocs"] += 1
        pid = self._free[shard].popleft()
        self.ref[pid] = 1
        self.stats["allocs"] += 1
        self.stats["peak_used"] = max(self.stats["peak_used"], self.used)
        return pid

    def _alloc_n(self, n: int, shard: int = 0, *, for_live: bool = False
                 ) -> Optional[List[int]]:
        """Allocate ``n`` pages of ``shard`` as ONE all-or-nothing free-list
        transaction: either all ``n`` come back (each refcount 1) or the free
        list and refcounts are left exactly as found — partially-grabbed
        pages were never written, so the rollback is an exact undo (no
        deref/scrub bookkeeping). The grouped-allocation primitive ``admit``
        builds on."""
        got: List[int] = []
        for _ in range(n):
            pid = self._alloc(shard, for_live=for_live)
            if pid is None:
                for p in reversed(got):
                    self.ref[p] = 0
                    self._free[shard].appendleft(p)
                self.stats["allocs"] -= len(got)
                return None
            got.append(pid)
        return got

    def _deref(self, pid: int) -> None:
        self.ref[pid] -= 1
        assert self.ref[pid] >= 0, pid
        if self.ref[pid] == 0:
            self._free[self.page_shard(pid)].append(pid)
            self.scrub_pending.append(pid)
            self.stats["frees"] += 1

    def drain_scrub(self) -> List[int]:
        """Pages freed since the last drain. Their device-side ``ppos`` rows
        still hold the previous tenant's positions, which would alias as
        valid entries for a new tenant at a different logical page — the
        engine sets them to -1 before the next jitted step."""
        out, self.scrub_pending = self.scrub_pending, []
        return out

    # ------------------------------------------------------- prefix index --

    def _chain_keys(self, prompt: Sequence[int], tag,
                    n_pages: int, shard: int = 0) -> List[int]:
        """Chained per-page index keys: ``key_i = hash((key_{i-1}, page_i
        tokens))`` — O(1) index storage per boundary instead of the full
        token tuple (which made a 32k prompt cost O(S^2/P) key memory), the
        vLLM block-hash scheme. 64-bit collisions are accepted as
        negligible. Keys are shard-tagged: a prefix registered on one shard
        must never be mapped into a slot on another (its pages would not be
        device-local there), so each shard keeps its own index namespace."""
        P = self.spec.page_size
        keys, prev = [], hash((id(type(self)), tag, shard))
        for i in range(n_pages):
            prev = hash((prev,
                         tuple(int(t) for t in prompt[i * P:(i + 1) * P])))
            keys.append(prev)
        return keys

    def lookup_prefix(self, prompt: Sequence[int], tag, shard: int = 0
                      ) -> Tuple[int, Optional[PrefixEntry]]:
        """Deepest registered full-page prefix of ``prompt`` under ``tag``
        on ``shard``, capped at ``len(prompt) - 1`` tokens so admission
        always re-prefills at least the last token (its logits seed
        sampling). Pure lookup: hit/LRU bookkeeping happens in ``admit``
        only when the admission commits, so a blocked request retried every
        engine step does not inflate the hit-rate metrics or refresh the
        entry's LRU clock."""
        P = self.spec.page_size
        n = min((len(prompt) - 1) // P, self.max_register_pages)
        best: Tuple[int, Optional[PrefixEntry]] = (0, None)
        for i, key in enumerate(self._chain_keys(prompt, tag, n, shard)):
            e = self.index.get(key)
            if e is not None:          # chains may have gaps (eviction/cap):
                best = ((i + 1) * P, e)  # deepest present boundary wins
        return best

    def register_prefix(self, slot: int, prompt: Sequence[int], tag,
                        n_tokens: int, mamba=None) -> None:
        """Pin the slot's first ``n_tokens // page_size`` pages as a shared
        prefix (idempotent per key; boundaries past ``max_register_pages``
        are not indexed)."""
        P = self.spec.page_size
        assert n_tokens % P == 0 and n_tokens > 0, n_tokens
        if n_tokens // P > self.max_register_pages:
            self.stats["register_capped"] += 1
            return
        key = self._chain_keys(prompt, tag, n_tokens // P,
                               self.slot_shard(slot))[-1]
        if key in self.index:
            return
        pages = tuple(int(p) for p in self.blocks[slot, : n_tokens // P])
        assert all(p != 0 for p in pages), (slot, pages)
        for p in pages:
            self.ref[p] += 1
        self.index[key] = PrefixEntry(pages, n_tokens, mamba,
                                      last_use=self._tick())
        self.stats["prefix_registered"] += 1

    def _evict_lru(self, shard: Optional[int] = None) -> bool:
        """Evict the LRU prefix entry (``shard`` filters to entries whose
        pages live on that shard — an entry's pages are always
        shard-homogeneous by construction). Returns False when no candidate
        exists, so shard-local pressure loops terminate even while other
        shards' entries populate the index."""
        keys = [k for k, e in self.index.items()
                if shard is None or self.page_shard(e.pages[0]) == shard]
        if not keys:
            return False
        key = min(keys, key=lambda k: self.index[k].last_use)
        for p in self.index.pop(key).pages:
            self._deref(p)
        self.stats["prefix_evicted"] += 1
        return True

    def flush_prefixes(self) -> None:
        """Drop every prefix entry (variant hot-swaps re-encode the pool in
        place, so cached prefixes no longer match any knob tag)."""
        while self.index:
            self._evict_lru()

    # ----------------------------------------------------------- slot ops --

    def admit(self, slot: int, prompt: Sequence[int], tag, *,
              reserve_tokens: int = 0) -> Optional[AdmitPlan]:
        """Build the slot's block table for ``prompt``: map shared prefix
        pages (refcount bump) and allocate private pages for the remainder.
        Returns None — with no state changed — when the pool is over budget
        (the request stays pending).

        ``reserve_tokens`` > 0 is the grouped/speculative path: the pool
        additionally maps the pages covering that many decode tokens past
        the prompt in the SAME free-list transaction, so the decode loop's
        ``ensure_decode_page`` finds them already mapped and the block table
        is pushed once per admission instead of once per page crossing.
        Reserved pages carry no valid entries yet (their ``ppos`` rows are
        scrubbed to -1, masking them out of attention) and are freed with
        the slot like any other private page. When the full group does not
        fit, admission falls back to prompt-only rather than blocking."""
        P = self.spec.page_size
        assert not self.slot_pages[slot], f"slot {slot} not freed"
        assert len(prompt) <= self.spec.max_pages * P, (len(prompt), self.spec)
        shard = self.slot_shard(slot)
        prompt_pages = -(-len(prompt) // P)
        if prompt_pages > self.spec.shard_pages - 1:
            # structurally impossible — retrying every step would spin the
            # engine through max_steps with the request silently unserved
            raise RuntimeError(
                f"prompt needs {prompt_pages} pages but the pool has "
                f"{self.spec.shard_pages - 1} usable on the slot's shard; "
                "size n_pages up")
        shared, entry = self.lookup_prefix(prompt, tag, shard)
        # feasibility gate BEFORE touching allocator state: a doomed attempt
        # must not evict prefix entries it cannot use. The engine's
        # page-aware packing retries several candidates per step while the
        # pool is blocked — without this gate every failed retry would run
        # _alloc's pressure loop and progressively drain the prefix cache.
        # ``evictable`` counts index pages only the index pins (ref 1):
        # evicting those both lowers ``used`` and refills the free list, so
        # the gate passing guarantees the allocation below succeeds.
        hit_pages = set(entry.pages) if entry is not None else set()
        evict_all = evict_shard = 0
        for e in self.index.values():
            for p in e.pages:
                if self.ref[p] == 1 and p not in hit_pages:
                    evict_all += 1
                    if self.page_shard(p) == shard:
                        evict_shard += 1
        # budget headroom can be relieved by evicting ANY shard's entries;
        # supply headroom only by this shard's free list + evictable pages
        head = min(max(self.limit - self.used, 0) + evict_all,
                   len(self._free[shard]) + evict_shard)
        want_full = min(max(-(-(len(prompt) + reserve_tokens) // P),
                            prompt_pages), self.spec.max_pages)
        n_total = next((c for c in dict.fromkeys([want_full, prompt_pages])
                        if c - shared // P <= head), None)
        if n_total is None:
            self.stats["blocked_admissions"] += 1
            return None
        if n_total < want_full:
            self.stats["grouped_fallbacks"] += 1
        n_new = n_total - shared // P
        if shared:
            # pin the hit pages BEFORE allocating fresh ones: under pressure
            # _alloc's LRU eviction may drop the hit entry itself, and
            # without the slot's ref its pages would be freed (and scrubbed)
            # while this admission is about to map them
            for p in entry.pages:
                self.ref[p] += 1
        fresh = self._alloc_n(n_new, shard)
        if fresh is None:              # unreachable after the gate, kept as
            if shared:                 # a safety net for future drift
                for p in entry.pages:
                    self._deref(p)
            self.stats["blocked_admissions"] += 1
            return None
        if shared:
            entry.hits += 1
            entry.last_use = self._tick()
            self.stats["prefix_hits"] += 1
        else:
            self.stats["prefix_misses"] += 1
        row = self.blocks[slot]
        row[:] = 0
        if shared:
            row[: shared // P] = entry.pages
        row[shared // P: shared // P + n_new] = fresh
        self.slot_pages[slot] = [int(p) for p in row[: shared // P + n_new]]
        self.stats["tokens_skipped"] += shared
        # register every unregistered full-page boundary beyond the shared
        # prefix (bounded by max_register_pages) — a future prompt sharing
        # only the first k pages must still hit (the target workload is
        # shared prefix + divergent tails)
        top = min(len(prompt) // P, self.max_register_pages) * P
        keys = self._chain_keys(prompt, tag, top // P, shard)
        reg = [b for b in range(shared + P, top + 1, P)
               if keys[b // P - 1] not in self.index]
        if len(prompt) // P > self.max_register_pages:
            self.stats["register_capped"] += 1
        reserved = n_total - prompt_pages
        if reserved:
            self.stats["grouped_admissions"] += 1
            self.stats["grouped_pages"] += reserved
        return AdmitPlan(shared, entry, reg, reserved)

    def ensure_decode_page(self, slot: int, position: int) -> bool:
        """Map the page holding ``position`` before a decode write lands
        there. Returns True when the block table changed (engine re-pushes).
        Live-request growth bypasses the reclaim limit by design."""
        P = self.spec.page_size
        lp = position // P
        if lp >= self.spec.max_pages:
            raise RuntimeError(
                f"slot {slot}: position {position} overflows the block table "
                f"({self.spec.max_pages} pages x {P}); paged serving does not "
                f"ring-wrap — size max_len >= prompt + max_new")
        if self.blocks[slot, lp] != 0:
            return False
        pid = self._alloc(self.slot_shard(slot), for_live=True)
        if pid is None:
            raise RuntimeError("page pool exhausted mid-decode "
                               f"(used={self.used}/{self.spec.usable})")
        self.blocks[slot, lp] = pid
        self.slot_pages[slot].append(pid)
        return True

    def ensure_decode_range(self, slot: int, start_pos: int,
                            end_pos: int) -> bool:
        """Host mirror of the megastep's in-scan cursor growth: map every
        page touched by decode writes at positions ``[start_pos, end_pos)``
        BEFORE the fused K-step executable is dispatched — the scan advances
        the cursor on device, so no per-token host round-trip exists to
        fault pages in lazily. Same live-growth semantics as
        ``ensure_decode_page`` (bypasses the reclaim limit, raises on
        exhaustion). Returns True when the block table changed (engine
        re-pushes before dispatch)."""
        if end_pos <= start_pos:
            return False
        P = self.spec.page_size
        changed = False
        for lp in range(start_pos // P, (end_pos - 1) // P + 1):
            changed |= self.ensure_decode_page(slot, lp * P)
        return changed

    def release_window_pages(self, slot: int, min_pos: int) -> bool:
        """Free the slot's leading pages that fell out of the attention
        window: every entry at position <= ``min_pos`` is masked by EVERY
        layer (the caller guarantees the arch is banded-only), so pages
        wholly at-or-below that boundary are dead weight. Deref + unmap
        them; prefix-index pins keep shared pages alive for future hits.
        Returns True when the block table changed (engine re-pushes)."""
        P = self.spec.page_size
        changed = False
        for lp in range(self.spec.max_pages):
            if (lp + 1) * P - 1 > min_pos:
                break                        # first page still in the band
            pid = int(self.blocks[slot, lp])
            if pid == 0:
                continue                     # already freed earlier
            self.blocks[slot, lp] = 0
            self.slot_pages[slot].remove(pid)
            self._deref(pid)
            self.stats["window_freed"] += 1
            changed = True
        return changed

    def free_slot(self, slot: int) -> bool:
        if not self.slot_pages[slot]:
            return False
        for p in self.slot_pages[slot]:
            self._deref(p)
        self.slot_pages[slot] = []
        self.blocks[slot] = 0
        return True

    # --------------------------------------------------------- background --

    def replenish(self, *, low: Optional[int] = None,
                  high: Optional[int] = None) -> int:
        """Watermark-based background reservation: keep immediately
        allocatable headroom (free pages under the reclaim limit) above a
        low watermark by evicting LRU prefix entries, topping back up to the
        high watermark. The engine calls this BETWEEN steps, so the eviction
        churn that ``_alloc`` would otherwise run inside an admission
        happens off the hot path. Returns the number of entries evicted."""
        if low is None:
            low = max(1, self.spec.usable // 8)
        if high is None:
            high = min(2 * low, self.spec.usable)
        # per-shard watermarks: headroom on one shard cannot serve another's
        # admissions, so each shard keeps its own share of the reservation
        # (ceil split keeps n_shards=1 behavior identical)
        ns = self.spec.n_shards
        lo, hi = -(-low // ns), -(-high // ns)

        def headroom(s: int) -> int:
            return min(len(self._free[s]), max(self.limit - self.used, 0))

        evicted = 0
        for s in range(ns):
            if headroom(s) >= lo:
                continue
            while headroom(s) < hi and self._evict_lru(s):
                evicted += 1
        self.stats["replenish_evictions"] += evicted
        return evicted

    def assert_consistent(self) -> None:
        """Audit the allocator invariants (test hook): every physical page
        is either free (refcount 0, unmapped, unpinned) or accounted for
        EXACTLY by slot mappings + prefix-index pins — so no sequence of
        grouped/speculative admissions, watermark evictions, completions,
        and reclaims can strand a page."""
        want: collections.Counter = collections.Counter()
        for pages in self.slot_pages:
            want.update(pages)
        for e in self.index.values():
            want.update(e.pages)
        flat = self.free
        free = set(flat)
        nulls = {s * self.spec.shard_pages for s in range(self.spec.n_shards)}
        assert len(free) == len(flat), "free list holds duplicates"
        assert not (nulls & free), "null page on a free list"
        for s, dq in enumerate(self._free):
            for p in dq:
                assert self.page_shard(p) == s, \
                    (p, s, "free page on the wrong shard's list")
        for pid in range(self.spec.n_pages):
            if pid in nulls:
                assert self.ref[pid] == 0 and want[pid] == 0, \
                    (pid, "null page allocated or mapped")
                continue
            if pid in free:
                assert self.ref[pid] == 0 and want[pid] == 0, \
                    (pid, int(self.ref[pid]), want[pid])
            else:
                assert int(self.ref[pid]) == want[pid] > 0, \
                    (pid, int(self.ref[pid]), want[pid])
        for slot in range(self.batch_slots):
            mapped = sorted(int(p) for p in self.blocks[slot] if p != 0)
            assert mapped == sorted(self.slot_pages[slot]), \
                (slot, mapped, self.slot_pages[slot])
            # slot affinity: every page a slot maps lives on its own shard,
            # so inside shard_map the block row resolves device-locally
            for p in self.slot_pages[slot]:
                assert self.page_shard(p) == self.slot_shard(slot), \
                    (slot, p, "page mapped across shards")
        for e in self.index.values():
            shards = {self.page_shard(p) for p in e.pages}
            assert len(shards) == 1, (e.pages, "prefix entry spans shards")

    # ------------------------------------------------------------ reclaim --

    def set_reclaimed(self, k: int) -> None:
        """Actuate the ``pool_pages`` knob: budget = usable - k * quantum.
        Shrinking evicts prefix entries until under budget (live pages are
        untouchable); both directions are recorded as reclaim events."""
        k = max(0, min(int(k), self.max_quanta))
        if k == self.reclaimed:
            return
        grow = k < self.reclaimed
        self.reclaimed = k
        evicted = 0
        while self.used > self.limit and self.index:
            self._evict_lru()
            evicted += 1
        self.stats["reclaim_events"] += 1
        self.stats.setdefault("reclaim_log", []).append(dict(
            action="grow" if grow else "shrink", reclaimed=k,
            limit=self.limit, used=self.used, evicted=evicted))

    def set_capacity_cut(self, k: int) -> None:
        """Actuate a QUOTA_CUT/QUOTA_RESTORE capacity event: ``k`` quanta of
        the pool are externally gone (a co-tenant's emergency grab), on top
        of whatever the arbiter has reclaimed. Same semantics as
        ``set_reclaimed`` — prefix entries evicted until under the new
        budget, live pages untouchable — but tracked separately so the
        Pliant ledger never has to account for quanta it did not take."""
        k = max(0, int(k))
        if k == self.capacity_cut:
            return
        self.capacity_cut = k
        evicted = 0
        while self.used > self.limit and self.index:
            self._evict_lru()
            evicted += 1
        self.stats["capacity_cut_events"] += 1
        self.stats.setdefault("capacity_log", []).append(dict(
            capacity_cut=k, limit=self.limit, used=self.used,
            evicted=evicted))

    # ------------------------------------------------------------- elastic --

    def migrate(self, spec: PageSpec) -> Tuple["PagePool", np.ndarray]:
        """Re-home every live slot's pages into a FRESH pool laid out by
        ``spec`` — the shard-count / pool-size change after a capacity event
        re-derives the slot-affinity decode plan. Returns ``(new_pool,
        perm)`` where ``perm[new_pid] = old_pid`` names the physical page
        whose contents must be copied there (-1 = no source, the page starts
        empty); the engine applies ``perm`` to the device-side page arrays.

        Live slots keep their logical block layout bit-for-bit; only the
        physical homes change, every page re-allocated on its slot's NEW
        affinity shard. A page shared by several slots (prefix hit) is
        duplicated — copy-on-write collapses to copies. Prefix-index entries
        are EVICTED, never migrated: keys are shard-tagged chained hashes
        and entries do not retain their tokens, so a re-homed entry could
        not be re-keyed for its new shard — the loss is cold misses
        (``stats["elastic_prefix_evicted"]``), never corruption. Allocation
        runs ``for_live`` (capacity floors must not block the move) and
        raises only when a slot's pages physically cannot fit its new
        shard — callers size pools so one full sequence per slot always
        fits (``spec_for`` guarantees it)."""
        assert spec.page_size == self.spec.page_size \
            and spec.max_pages == self.spec.max_pages, (spec, self.spec)
        new = PagePool(spec, self.batch_slots, reclaim_quantum=self.quantum,
                       max_register_pages=self.max_register_pages)
        carried = {k: v for k, v in self.stats.items()}
        carried["elastic_migrations"] = \
            self.stats["elastic_migrations"] + 1
        carried["elastic_prefix_evicted"] = \
            self.stats["elastic_prefix_evicted"] + len(self.index)
        new.stats.update(carried)
        new.reclaimed = min(self.reclaimed, new.max_quanta)
        new.capacity_cut = self.capacity_cut
        perm = np.full(spec.n_pages, -1, np.int64)
        for slot in range(self.batch_slots):
            shard = new.slot_shard(slot)
            for lp in range(self.spec.max_pages):
                old_pid = int(self.blocks[slot, lp])
                if old_pid == 0:
                    continue
                new_pid = new._alloc(shard, for_live=True)
                if new_pid is None:
                    raise RuntimeError(
                        f"migrate: slot {slot}'s pages do not fit shard "
                        f"{shard} of {spec} — pool sized too small for the "
                        "live set")
                new.blocks[slot, lp] = new_pid
                new.slot_pages[slot].append(new_pid)
                perm[new_pid] = old_pid
        return new, perm
