"""Batched serving engine: continuous-batching slots over the decode step,
run under Pliant control.

Each slot holds one request's progress; finished slots are refilled from the
queue without stopping the batch ("continuous batching"). Admission is
chunked prefill: the prompt streams through fixed-size full-sequence chunks
(``serve.prefill``) — no O(prompt) token-by-token warmup on the decode path,
so 32k prompts admit in a handful of executable calls.

Two cache data models, selected by ``paged``:

* **dense** (default): per-slot ``max_len`` rings; admission prefills a
  single-request cache and slot-scatters it (``serve.slots``).
* **paged**: a shared physical page pool + per-slot block tables
  (``serve.pages.PagePool`` owns allocation host-side; the jitted paths in
  ``models.attention`` gather/scatter through the tables). Admission maps
  shared prompt-prefix pages copy-on-write — a prefix hit SKIPS those
  prefill chunks entirely — and prefills the remainder straight into the
  pool; completion returns pages to the free list. The pool budget is a
  Pliant knob: when a ``PliantRuntime`` is attached its RECLAIM/RETURN
  actions shrink/regrow ``pool_pages`` (``attach_reclaimer``), evicting
  prefix-cache pages first and never touching live requests.

Serving variants come from a ``VariantTable`` (the explorer's serving grid):
every variant's decode executable is registered up front and the active one
is swapped at a step boundary — an O(µs) dictionary lookup, the DynamoRIO
function-pointer swap analogue. When a ``PliantRuntime`` is attached, the
engine feeds per-token latency to its ``LatencyMonitor`` and actuates the
controller's decisions, converting cache dtype when a swap crosses the
``kv_quant`` boundary. Under a mesh, params shard via
``dist.param_shardings`` and caches via ``dist.cache_shardings``.
"""
from __future__ import annotations

import collections
import contextlib
import time
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.knobs import ApproxKnobs, PRECISE
from repro.configs.base import MAMBA, ModelConfig, ShapeConfig
from repro.core.runtime import PliantRuntime
from repro.core.variants import VariantTable
from repro.models import lm
from repro.models.attention import PagedKVCache
from repro.models.mamba2 import MambaCache
from repro.serve import pages as pages_mod
from repro.serve import slots as slots_mod
from repro.train import step as step_mod


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False
    t_arrival: float = 0.0    # driver-set (open-loop client)
    t_admit: float = 0.0      # admission COMPLETION (prefill done, slot live)
    token_times: List[float] = field(default_factory=list)


@dataclass
class ServeEngine:
    cfg: ModelConfig
    batch_slots: int
    max_len: int
    knobs: ApproxKnobs = PRECISE       # single-variant mode (no table)
    temperature: float = 0.0           # 0.0 = greedy
    params: object = None
    table: Optional[VariantTable] = None
    runtime: Optional[PliantRuntime] = None
    mesh: object = None
    policy: str = "tp"                 # param sharding policy under a mesh
    prefill_chunk: int = 16
    seed: int = 0
    cache_dtype: object = jnp.float32
    paged: bool = False                # paged pool instead of dense rings
    page_size: int = 8
    n_pages: int = 0                   # 0 = auto (serve.pages.spec_for)
    max_prefill_exes: int = 16         # LRU bound on admission executables

    def __post_init__(self):
        if self.runtime is not None:
            self.table = self.runtime.table
        self._variant_knobs = ([v.knobs for v in self.table.variants]
                               if self.table is not None else [self.knobs])
        self._active = 0
        self.pool: Optional[pages_mod.PagePool] = None
        self._page_spec = None
        self.stores: List[pages_mod.CacheStore] = []
        if self.paged:
            self._page_spec = pages_mod.spec_for(
                self.batch_slots, self.max_len, self.page_size, self.n_pages)
            self.pool = pages_mod.PagePool(self._page_spec, self.batch_slots)
            # one store per cache kind behind the shared CacheStore protocol:
            # the page pool for attention state, the trivial per-slot store
            # for SSM state — the engine frees every kind uniformly
            self.stores = [self.pool]
            if MAMBA in self.cfg.pattern:
                self.stores.append(pages_mod.MambaSlotStore())
        self._param_sh = self._cache_sh = None
        if self.mesh is not None:
            from repro.dist import sharding as dist_sharding
            self._param_sh = dist_sharding.param_shardings(
                self.cfg, self.mesh, self.policy)
            shp = ShapeConfig("serve", self.max_len, self.batch_slots,
                              "decode")
            self._cache_sh, _ = dist_sharding.cache_shardings(
                self.cfg, shp, self.mesh, paged=self._page_spec)
            with self._ctx():
                self.params = jax.device_put(self.params, self._param_sh)

        # the variant table of decode executables: registered once up front,
        # hot-swapped between steps (no recompilation on the critical path).
        # Engine-owned, never written into the (possibly shared) table —
        # executables are lowered against THIS engine's mesh/shardings
        self._decodes = {
            i: self._lower_decode(step_mod.make_serve_step(self.cfg, k))
            for i, k in enumerate(self._variant_knobs)}
        # admission executables, keyed by (knobs, chunk len, paged) — NOT by
        # variant index, so table entries with identical admission knobs
        # share one compiled chunk cell — and LRU-bounded
        self._prefills: "collections.OrderedDict[Tuple, object]" = \
            collections.OrderedDict()
        self._insert = jax.jit(slots_mod.insert_request)

        self.caches = self._init_caches(self.active_knobs.kv_quant)
        self.positions = np.zeros(self.batch_slots, np.int32)
        self.slots: List[Optional[Request]] = [None] * self.batch_slots
        self.pending: Deque[Request] = collections.deque()
        self.cur_tokens = np.zeros(self.batch_slots, np.int32)
        self.step_latencies: List[float] = []
        self.admit_latencies: List[float] = []
        self.swaps: List[Tuple[int, int]] = []   # (step index, variant index)
        self._token_lat: List[float] = []        # unflushed monitor samples
        self._rng = np.random.default_rng(self.seed)
        if (self.paged and self.runtime is not None
                and self.runtime.reshard_fn is None):
            # expose pool_pages as the runtime's reclaimable knob: RECLAIM
            # shrinks the page budget (prefix cache evicted first), RETURN
            # grows it back
            self.runtime.attach_reclaimer(self.pool.set_reclaimed,
                                          max_reclaim=self.pool.max_quanta)

    # ------------------------------------------------------------ variants --

    @property
    def active_variant(self) -> int:
        return self._active

    @property
    def active_knobs(self) -> ApproxKnobs:
        return self._variant_knobs[self._active]

    def set_variant(self, idx: int) -> None:
        """Hot-swap the decode executable at a step boundary, converting the
        KV rings/pages when the swap crosses the ``kv_quant`` boundary."""
        if idx == self._active:
            return
        old, new = self.active_knobs, self._variant_knobs[idx]
        if old.kv_quant != new.kv_quant:
            with self._ctx():
                self.caches = slots_mod.convert_caches(
                    self.caches, new.kv_quant, self.cache_dtype)
                if self._cache_sh is not None:
                    self.caches = jax.device_put(self.caches, self._cache_sh)
        if self.pool is not None and old != new:
            # prefix entries are tagged by the knobs that computed them; a
            # swap re-encodes the pool in place, so drop the stale index
            self.pool.flush_prefixes()
        self._active = idx
        self.swaps.append((len(self.step_latencies), idx))

    def retire_variant(self, idx: int) -> None:
        """Drop a retired table entry's executables. Admission cells are
        knobs-keyed, so they survive while any live variant shares the
        knobs and are evicted with the last user."""
        assert idx != self._active, "cannot retire the active variant"
        self._decodes.pop(idx, None)
        kn = self._variant_knobs[idx]
        if any(k == kn for i, k in enumerate(self._variant_knobs)
               if i != idx and i in self._decodes):
            return
        for key in [k for k in self._prefills if k[0] == kn]:
            del self._prefills[key]

    def _lower_decode(self, step):
        if self.mesh is None:
            return jax.jit(step)
        return jax.jit(step,
                       in_shardings=(self._param_sh, None, None,
                                     self._cache_sh),
                       out_shardings=(None, self._cache_sh))

    def _prefill_exe(self, chunk_len: int):
        key = (self.active_knobs, chunk_len, self.paged)
        fn = self._prefills.get(key)
        if fn is not None:
            self._prefills.move_to_end(key)
            return fn
        if self.paged:
            step = step_mod.make_paged_admission_step(self.cfg,
                                                      self.active_knobs)
            if self.mesh is None:
                fn = jax.jit(step)
            else:
                fn = jax.jit(step,
                             in_shardings=(self._param_sh, None, None,
                                           self._cache_sh, None),
                             out_shardings=(None, self._cache_sh))
        else:
            step = step_mod.make_admission_step(self.cfg, self.active_knobs)
            if self.mesh is None:
                fn = jax.jit(step)
            else:
                fn = jax.jit(step, in_shardings=(self._param_sh, None, None,
                                                 None))
        self._prefills[key] = fn
        while len(self._prefills) > self.max_prefill_exes:
            self._prefills.popitem(last=False)
        return fn

    # ------------------------------------------------------------- helpers --

    def _ctx(self):
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.dist import compat
        return compat.set_mesh(self.mesh)

    def _init_caches(self, quantized: bool):
        if self.paged:
            sp = self._page_spec
            caches = lm.init_paged_caches(
                self.cfg, self.batch_slots, sp.n_pages, sp.page_size,
                sp.max_pages, dtype=self.cache_dtype, quantized=quantized)
        else:
            caches = lm.init_caches(self.cfg, self.batch_slots, self.max_len,
                                    dtype=self.cache_dtype,
                                    quantized=quantized)
        if self._cache_sh is not None:
            with self._ctx():
                caches = jax.device_put(caches, self._cache_sh)
        return caches

    def _sample(self, logits_row: np.ndarray) -> int:
        if self.temperature <= 0.0:
            return int(np.argmax(logits_row))
        z = logits_row.astype(np.float64) / self.temperature
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self._rng.choice(p.size, p=p))

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    # ------------------------------------------------------ paged plumbing --

    def _free_slot(self, slot: int) -> bool:
        """Release a finished request's cache residency across every store.
        Returns True when device-visible mapping state changed."""
        dirty = False
        for store in self.stores:
            dirty |= store.free_slot(slot)
        return dirty

    def _push_blocks(self) -> None:
        """Mirror the host block tables into the device caches (host-side
        allocation between steps; jitted steps only read the tables) and
        scrub freed pages' stale positions before they can be reused."""
        bt = jnp.asarray(self.pool.blocks)
        scrub = self.pool.drain_scrub()
        pids = jnp.asarray(scrub, jnp.int32) if scrub else None

        def one(c):
            if isinstance(c, PagedKVCache):
                ppos = c.ppos if pids is None else \
                    c.ppos.at[:, pids].set(-1)
                return c._replace(
                    ppos=ppos,
                    block=jnp.broadcast_to(bt[None], c.block.shape))
            return c

        self.caches = tuple(one(c) for c in self.caches)
        if self._cache_sh is not None:
            with self._ctx():
                self.caches = jax.device_put(self.caches, self._cache_sh)

    def _mamba_snapshot(self, slot: int):
        """Host copy of the slot's SSM state rows (prefix-boundary snapshot
        carried by the prefix index; None for attention-only archs)."""
        snap = {}
        for ci, c in enumerate(self.caches):
            if isinstance(c, MambaCache):
                snap[ci] = MambaCache(*(np.asarray(x[:, slot]) for x in c))
        return snap or None

    def _set_mamba_rows(self, slot: int, snap) -> None:
        """Seed the slot's SSM rows for a fresh admission: the prefix-entry
        snapshot on a hit, zeros otherwise — the previous tenant's state must
        never leak into a new request (the dense path gets this for free
        from its fresh single-request cache + insert)."""
        if not any(isinstance(c, MambaCache) for c in self.caches):
            return
        caches = list(self.caches)
        for ci, c in enumerate(self.caches):
            if not isinstance(c, MambaCache):
                continue
            row = snap.get(ci) if snap else None
            caches[ci] = MambaCache(*(
                x.at[:, slot].set(jnp.zeros_like(x[:, slot]) if r is None
                                  else jnp.asarray(r))
                for x, r in zip(c, row or (None,) * len(c))))
        self.caches = tuple(caches)
        if self._cache_sh is not None:
            with self._ctx():
                self.caches = jax.device_put(self.caches, self._cache_sh)

    # ----------------------------------------------------------- admission --

    def _chunked_prefill(self, prompt: List[int]):
        """Dense path: stream the prompt through fixed-size chunks into a
        fresh single-request cache. Returns (last-token logits, caches)."""
        knobs = self.active_knobs
        caches = lm.init_caches(self.cfg, 1, self.max_len,
                                dtype=self.cache_dtype,
                                quantized=knobs.kv_quant)
        toks = np.asarray(prompt, np.int32)
        S, start, logits = len(prompt), 0, None
        with self._ctx():
            while start < S:
                C = min(self.prefill_chunk, S - start)
                logits, caches = self._prefill_exe(C)(
                    self.params, jnp.asarray(toks[None, start:start + C]),
                    jnp.asarray(start, jnp.int32), caches)
                start += C
        return logits, caches

    def _paged_prefill(self, slot: int, req: Request):
        """Paged path: map pages (sharing registered prompt prefixes — a hit
        skips those chunks entirely), prefill the remainder straight into
        the pool, and register the longest full-page prefix with its SSM
        boundary snapshot. Returns last-token logits, or None when the pool
        is over budget (request stays pending)."""
        prompt = req.prompt
        plan = self.pool.admit(slot, prompt, self.active_knobs)
        if plan is None:
            return None
        self._push_blocks()
        snap = plan.entry.mamba if (plan.shared_tokens and plan.entry) \
            else None
        self._set_mamba_rows(slot, snap)
        toks = np.asarray(prompt, np.int32)
        S = len(prompt)
        state = {"start": plan.shared_tokens, "logits": None}
        sl = jnp.asarray(slot, jnp.int32)

        def run_to(end: int) -> None:
            with self._ctx():
                while state["start"] < end:
                    start = state["start"]
                    C = min(self.prefill_chunk, end - start)
                    state["logits"], self.caches = self._prefill_exe(C)(
                        self.params,
                        jnp.asarray(toks[None, start:start + C]),
                        jnp.asarray(start, jnp.int32), self.caches, sl)
                    state["start"] += C

        has_mamba = any(isinstance(c, MambaCache) for c in self.caches)
        if has_mamba:
            # pause prefill at each boundary so its SSM snapshot matches
            for b in plan.register:
                run_to(b)
                self.pool.register_prefix(slot, prompt, self.active_knobs, b,
                                          mamba=self._mamba_snapshot(slot))
            run_to(S)
        else:
            # attention-only: pages are position-addressed, registration is
            # pure bookkeeping — no need to fragment the chunk stream
            run_to(S)
            for b in plan.register:
                self.pool.register_prefix(slot, prompt, self.active_knobs, b)
        # lookup caps sharing at len(prompt)-1 tokens, so at least one chunk
        # always ran and produced the sampling logits
        assert state["logits"] is not None
        return state["logits"]

    def _admit(self) -> None:
        for i in range(self.batch_slots):
            while self.slots[i] is None and self.pending:
                req = self.pending[0]
                assert len(req.prompt) <= self.max_len, \
                    (len(req.prompt), self.max_len)
                if self.paged:
                    assert len(req.prompt) + req.max_new <= \
                        self._page_spec.max_pages * self.page_size, \
                        "paged serving does not ring-wrap: need " \
                        "max_len >= prompt + max_new"
                t0 = time.perf_counter()
                if self.paged:
                    logits = self._paged_prefill(i, req)
                    if logits is None:       # pool over budget: stop admitting
                        return
                else:
                    logits, rcaches = self._chunked_prefill(req.prompt)
                    with self._ctx():
                        self.caches = self._insert(self.caches, rcaches, i)
                        if self._cache_sh is not None:
                            self.caches = jax.device_put(self.caches,
                                                         self._cache_sh)
                self.pending.popleft()
                tok = self._sample(np.asarray(logits)[0])
                now = time.perf_counter()
                self.admit_latencies.append(now - t0)
                self._token_lat.append(now - t0)   # TTFT sample
                req.t_admit = now                  # admission COMPLETION
                req.out.append(tok)
                req.token_times.append(now)
                if len(req.out) >= req.max_new:
                    req.done = True                # 1-token request: no slot
                    if self.paged and self._free_slot(i):
                        self._push_blocks()
                    continue
                self.positions[i] = len(req.prompt)
                self.cur_tokens[i] = tok
                self.slots[i] = req

    # --------------------------------------------------------------- steps --

    def step(self) -> None:
        """One engine step: admit pending requests (chunked prefill), decode
        one token for every active slot, then tick the Pliant control loop."""
        self._admit()
        if all(s is None for s in self.slots):
            self._control_tick()       # flush TTFT samples of 1-token admits
            return
        if self.paged:
            # map each live slot's write page before the step scatters to it
            # (live growth bypasses the reclaim limit — see serve.pages)
            dirty = False
            for i, req in enumerate(self.slots):
                if req is not None:
                    dirty |= self.pool.ensure_decode_page(
                        i, int(self.positions[i]))
            if dirty:
                self._push_blocks()
        t0 = time.perf_counter()
        with self._ctx():
            toks = jnp.asarray(self.cur_tokens)[:, None]
            pos = jnp.asarray(self.positions)
            logits, self.caches = self._decodes[self._active](
                self.params, toks, pos, self.caches)
            logits = np.asarray(logits)
        dt = time.perf_counter() - t0
        self.step_latencies.append(dt)
        now = time.perf_counter()
        n_emitted = 0
        freed = False
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.positions[i] += 1
            nxt = self._sample(logits[i])
            req.out.append(nxt)
            req.token_times.append(now)
            self.cur_tokens[i] = nxt
            n_emitted += 1
            if len(req.out) >= req.max_new:
                req.done = True
                self.slots[i] = None            # slot freed: continuous batch
                if self.paged:
                    freed |= self._free_slot(i)
        if freed:
            self._push_blocks()
        self._token_lat.extend([dt] * n_emitted)
        self._control_tick()

    def _control_tick(self) -> None:
        """Monitor -> controller -> actuator at the step boundary."""
        if self.runtime is None:
            self._token_lat.clear()
            return
        if self._token_lat:
            self.runtime.monitor.record_many(self._token_lat)
            self._token_lat.clear()
        self.runtime.maybe_decide()
        if self.runtime.active_variant != self._active:
            self.set_variant(self.runtime.active_variant)

    def run(self, max_steps: int = 10_000) -> None:
        steps = 0
        while (self.pending or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            self.step()
            steps += 1
