"""Batched serving engine: continuous-batching slots over the decode step.

Each slot holds one request's progress; finished slots are refilled from the
queue without stopping the batch ("continuous batching"). The Pliant serving
knobs (int8 matmuls, int8 KV cache) select which compiled decode executable
runs — switched between steps exactly like training variants.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.knobs import ApproxKnobs, PRECISE
from repro.configs.base import ModelConfig
from repro.models import api, lm
from repro.train import step as step_mod


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False
    cursor: int = 0       # next prompt token to feed (cache-warmup progress)


@dataclass
class ServeEngine:
    cfg: ModelConfig
    batch_slots: int
    max_len: int
    knobs: ApproxKnobs = PRECISE
    temperature: float = 0.0
    params: object = None

    def __post_init__(self):
        self._decode = jax.jit(
            step_mod.make_serve_step(self.cfg, self.knobs))
        self.caches = lm.init_caches(
            self.cfg, self.batch_slots, self.max_len,
            dtype=jnp.float32, quantized=self.knobs.kv_quant)
        self.positions = np.zeros(self.batch_slots, np.int32)
        self.slots: List[Optional[Request]] = [None] * self.batch_slots
        self.pending: List[Request] = []
        self.cur_tokens = np.zeros(self.batch_slots, np.int32)
        self.step_latencies: List[float] = []

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def _reset_slot_cache(self, i: int) -> None:
        """Invalidate slot i's cache rows (stale entries must never attend)."""
        def reset(c):
            if hasattr(c, "pos"):            # attention KVCache
                return c._replace(pos=c.pos.at[:, i].set(-1))
            return c._replace(                # MambaCache
                conv_x=c.conv_x.at[:, i].set(0),
                conv_bc=c.conv_bc.at[:, i].set(0),
                state=c.state.at[:, i].set(0))
        self.caches = tuple(reset(c) for c in self.caches)

    def _fill_slots(self) -> None:
        for i in range(self.batch_slots):
            if self.slots[i] is None and self.pending:
                req = self.pending.pop(0)
                self.slots[i] = req
                self._reset_slot_cache(i)
                # prompt tokens are fed through decode steps (cache warmup)
                req.cursor = 0
                self.positions[i] = 0
                self.cur_tokens[i] = req.prompt[0]

    def step(self) -> None:
        """One engine step: decode one token for every active slot."""
        self._fill_slots()
        if all(s is None for s in self.slots):
            return
        t0 = time.perf_counter()
        toks = jnp.asarray(self.cur_tokens)[:, None]
        pos = jnp.asarray(self.positions)
        logits, self.caches = self._decode(self.params, toks, pos,
                                           self.caches)
        logits = np.asarray(logits)
        self.step_latencies.append(time.perf_counter() - t0)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.positions[i] += 1
            if req.cursor + 1 < len(req.prompt):
                # still consuming the prompt
                req.cursor += 1
                self.cur_tokens[i] = req.prompt[req.cursor]
                continue
            nxt = int(np.argmax(logits[i]))
            req.out.append(nxt)
            self.cur_tokens[i] = nxt
            if len(req.out) >= req.max_new:
                req.done = True
                self.slots[i] = None            # slot freed: continuous batch

    def run(self, max_steps: int = 10_000) -> None:
        steps = 0
        while (self.pending or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            self.step()
            steps += 1
