"""Batched serving engine: continuous-batching slots over the decode step,
run under Pliant control.

Each slot holds one request's progress; finished slots are refilled from the
queue without stopping the batch ("continuous batching"). Admission is
chunked prefill: the prompt streams through fixed-size full-sequence chunks
(``serve.prefill.prefill_chunk``) into a single-request cache that is then
slot-scattered into the batched caches (``serve.slots``) — no O(prompt)
token-by-token warmup on the decode path, so 32k prompts admit in a handful
of executable calls.

Serving variants come from a ``VariantTable`` (the explorer's serving grid):
every variant's decode executable is registered up front and the active one
is swapped at a step boundary — an O(µs) dictionary lookup, the DynamoRIO
function-pointer swap analogue. When a ``PliantRuntime`` is attached, the
engine feeds per-token latency to its ``LatencyMonitor`` and actuates the
controller's decisions, converting cache dtype when a swap crosses the
``kv_quant`` boundary. Under a mesh, params shard via
``dist.param_shardings`` and caches via ``dist.cache_shardings``.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.knobs import ApproxKnobs, PRECISE
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.runtime import PliantRuntime
from repro.core.variants import VariantTable
from repro.models import lm
from repro.serve import slots as slots_mod
from repro.train import step as step_mod


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False
    t_arrival: float = 0.0    # driver-set (open-loop client)
    t_admit: float = 0.0
    token_times: List[float] = field(default_factory=list)


@dataclass
class ServeEngine:
    cfg: ModelConfig
    batch_slots: int
    max_len: int
    knobs: ApproxKnobs = PRECISE       # single-variant mode (no table)
    temperature: float = 0.0           # 0.0 = greedy
    params: object = None
    table: Optional[VariantTable] = None
    runtime: Optional[PliantRuntime] = None
    mesh: object = None
    policy: str = "tp"                 # param sharding policy under a mesh
    prefill_chunk: int = 16
    seed: int = 0
    cache_dtype: object = jnp.float32

    def __post_init__(self):
        if self.runtime is not None:
            self.table = self.runtime.table
        self._variant_knobs = ([v.knobs for v in self.table.variants]
                               if self.table is not None else [self.knobs])
        self._active = 0
        self._param_sh = self._cache_sh = None
        if self.mesh is not None:
            from repro.dist import sharding as dist_sharding
            self._param_sh = dist_sharding.param_shardings(
                self.cfg, self.mesh, self.policy)
            shp = ShapeConfig("serve", self.max_len, self.batch_slots,
                              "decode")
            self._cache_sh, _ = dist_sharding.cache_shardings(self.cfg, shp,
                                                              self.mesh)
            with self._ctx():
                self.params = jax.device_put(self.params, self._param_sh)

        # the variant table of decode executables: registered once up front,
        # hot-swapped between steps (no recompilation on the critical path).
        # Engine-owned, never written into the (possibly shared) table —
        # executables are lowered against THIS engine's mesh/shardings
        self._decodes = {
            i: self._lower_decode(step_mod.make_serve_step(self.cfg, k))
            for i, k in enumerate(self._variant_knobs)}
        self._prefills: Dict[Tuple[int, int], object] = {}
        self._insert = jax.jit(slots_mod.insert_request)

        self.caches = self._init_caches(self.active_knobs.kv_quant)
        self.positions = np.zeros(self.batch_slots, np.int32)
        self.slots: List[Optional[Request]] = [None] * self.batch_slots
        self.pending: List[Request] = []
        self.cur_tokens = np.zeros(self.batch_slots, np.int32)
        self.step_latencies: List[float] = []
        self.admit_latencies: List[float] = []
        self.swaps: List[Tuple[int, int]] = []   # (step index, variant index)
        self._token_lat: List[float] = []        # unflushed monitor samples
        self._rng = np.random.default_rng(self.seed)

    # ------------------------------------------------------------ variants --

    @property
    def active_variant(self) -> int:
        return self._active

    @property
    def active_knobs(self) -> ApproxKnobs:
        return self._variant_knobs[self._active]

    def set_variant(self, idx: int) -> None:
        """Hot-swap the decode executable at a step boundary, converting the
        KV rings when the swap crosses the ``kv_quant`` boundary."""
        if idx == self._active:
            return
        old, new = self.active_knobs, self._variant_knobs[idx]
        if old.kv_quant != new.kv_quant:
            with self._ctx():
                self.caches = slots_mod.convert_caches(
                    self.caches, new.kv_quant, self.cache_dtype)
                if self._cache_sh is not None:
                    self.caches = jax.device_put(self.caches, self._cache_sh)
        self._active = idx
        self.swaps.append((len(self.step_latencies), idx))

    def _lower_decode(self, step):
        if self.mesh is None:
            return jax.jit(step)
        return jax.jit(step,
                       in_shardings=(self._param_sh, None, None,
                                     self._cache_sh),
                       out_shardings=(None, self._cache_sh))

    def _prefill_exe(self, chunk_len: int):
        key = (self._active, chunk_len)
        fn = self._prefills.get(key)
        if fn is None:
            step = step_mod.make_admission_step(self.cfg, self.active_knobs)
            if self.mesh is None:
                fn = jax.jit(step)
            else:
                fn = jax.jit(step, in_shardings=(self._param_sh, None, None,
                                                 None))
            self._prefills[key] = fn
        return fn

    # ------------------------------------------------------------- helpers --

    def _ctx(self):
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.dist import compat
        return compat.set_mesh(self.mesh)

    def _init_caches(self, quantized: bool):
        caches = lm.init_caches(self.cfg, self.batch_slots, self.max_len,
                                dtype=self.cache_dtype, quantized=quantized)
        if self._cache_sh is not None:
            with self._ctx():
                caches = jax.device_put(caches, self._cache_sh)
        return caches

    def _sample(self, logits_row: np.ndarray) -> int:
        if self.temperature <= 0.0:
            return int(np.argmax(logits_row))
        z = logits_row.astype(np.float64) / self.temperature
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self._rng.choice(p.size, p=p))

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    # ----------------------------------------------------------- admission --

    def _chunked_prefill(self, prompt: List[int]):
        """Stream the prompt through fixed-size chunks into a fresh
        single-request cache. Returns (last-token logits, caches)."""
        knobs = self.active_knobs
        caches = lm.init_caches(self.cfg, 1, self.max_len,
                                dtype=self.cache_dtype,
                                quantized=knobs.kv_quant)
        toks = np.asarray(prompt, np.int32)
        S, start, logits = len(prompt), 0, None
        with self._ctx():
            while start < S:
                C = min(self.prefill_chunk, S - start)
                logits, caches = self._prefill_exe(C)(
                    self.params, jnp.asarray(toks[None, start:start + C]),
                    jnp.asarray(start, jnp.int32), caches)
                start += C
        return logits, caches

    def _admit(self) -> None:
        for i in range(self.batch_slots):
            while self.slots[i] is None and self.pending:
                req = self.pending.pop(0)
                assert len(req.prompt) <= self.max_len, \
                    (len(req.prompt), self.max_len)
                t0 = time.perf_counter()
                logits, rcaches = self._chunked_prefill(req.prompt)
                with self._ctx():
                    self.caches = self._insert(self.caches, rcaches, i)
                    if self._cache_sh is not None:
                        self.caches = jax.device_put(self.caches,
                                                     self._cache_sh)
                tok = self._sample(np.asarray(logits)[0])
                now = time.perf_counter()
                self.admit_latencies.append(now - t0)
                self._token_lat.append(now - t0)   # TTFT sample
                req.t_admit = t0
                req.out.append(tok)
                req.token_times.append(now)
                if len(req.out) >= req.max_new:
                    req.done = True                # 1-token request: no slot
                    continue
                self.positions[i] = len(req.prompt)
                self.cur_tokens[i] = tok
                self.slots[i] = req

    # --------------------------------------------------------------- steps --

    def step(self) -> None:
        """One engine step: admit pending requests (chunked prefill), decode
        one token for every active slot, then tick the Pliant control loop."""
        self._admit()
        if all(s is None for s in self.slots):
            self._control_tick()       # flush TTFT samples of 1-token admits
            return
        t0 = time.perf_counter()
        with self._ctx():
            toks = jnp.asarray(self.cur_tokens)[:, None]
            pos = jnp.asarray(self.positions)
            logits, self.caches = self._decodes[self._active](
                self.params, toks, pos, self.caches)
            logits = np.asarray(logits)
        dt = time.perf_counter() - t0
        self.step_latencies.append(dt)
        now = time.perf_counter()
        n_emitted = 0
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.positions[i] += 1
            nxt = self._sample(logits[i])
            req.out.append(nxt)
            req.token_times.append(now)
            self.cur_tokens[i] = nxt
            n_emitted += 1
            if len(req.out) >= req.max_new:
                req.done = True
                self.slots[i] = None            # slot freed: continuous batch
        self._token_lat.extend([dt] * n_emitted)
        self._control_tick()

    def _control_tick(self) -> None:
        """Monitor -> controller -> actuator at the step boundary."""
        if self.runtime is None:
            self._token_lat.clear()
            return
        if self._token_lat:
            self.runtime.monitor.record_many(self._token_lat)
            self._token_lat.clear()
        self.runtime.maybe_decide()
        if self.runtime.active_variant != self._active:
            self.set_variant(self.runtime.active_variant)

    def run(self, max_steps: int = 10_000) -> None:
        steps = 0
        while (self.pending or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            self.step()
            steps += 1
