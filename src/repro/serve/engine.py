"""Batched serving engine: continuous-batching slots over the decode step,
run under Pliant control.

Each slot holds one request's progress; finished slots are refilled from the
queue without stopping the batch ("continuous batching"). Admission is
chunked prefill: the prompt streams through fixed-size full-sequence chunks
(``serve.prefill``) — no O(prompt) token-by-token warmup on the decode path,
so 32k prompts admit in a handful of executable calls.

Two cache data models, selected by ``paged``:

* **dense** (default): per-slot ``max_len`` rings; admission prefills a
  single-request cache and slot-scatters it (``serve.slots``).
* **paged**: a shared physical page pool + per-slot block tables
  (``serve.pages.PagePool`` owns allocation host-side; the jitted paths in
  ``models.attention`` gather/scatter through the tables). Admission maps
  shared prompt-prefix pages copy-on-write — a prefix hit SKIPS those
  prefill chunks entirely — and prefills the remainder straight into the
  pool; completion returns pages to the free list. The pool budget is a
  Pliant knob: the engine binds itself to an attached ``PliantRuntime`` as
  a ``core.tenant.ServeTenant`` whose reclaimable quanta are pool pages —
  RECLAIM/RETURN shrink/regrow ``pool_pages``, evicting prefix-cache pages
  first and never touching live requests.

The paged loop is **continuously batched** and **stall-free**: admission
prefill never runs to completion inside ``step()``. EVERY free slot opens
its own in-flight admission each step (no wave barrier — freed slots refill
while their neighbours keep decoding), and the step advances the in-flight
admissions round-robin under a QoS-aware chunk budget: ONE bounded chunk
per step while any decoder is live (unless the attached runtime's
``LatencyMonitor`` reports p99 comfortably inside the QoS target — the
``qos_guard`` band), bursting up to ``max_admission_chunks`` when there is
no decoder to protect or headroom to spare. A long prompt therefore adds at
most one budget's worth of work between any two decode steps. The decode
executable takes a per-slot ``active`` mask so admitting slots' dead batch
rows cannot scatter garbage into their (already mapped) pages or SSM rows.
Admission is also **page-aware packed**: when the head of the queue does
not fit the pool budget, the first of the leading ``pack_window`` pending
requests that does fit is admitted instead — and after ``max_head_skips``
consecutive head skips admission reverts to strict FIFO, so head-of-line
blocking AND starvation are both bounded. Admission allocates grouped:
prompt pages AND the request's projected decode pages map in one free-list
transaction (``serve.pages``), so the decode hot loop almost never touches
the allocator; a per-step ``PagePool.replenish`` keeps free-list headroom
above a watermark by evicting prefix entries off the admission path.
Banded-attention archs (every attention layer LOCAL) skip the speculative
reservation — they free pages that fall out of the window as decode
advances, keeping pool occupancy flat for long generations. On device,
hybrid decode is ONE fused executable per step (attention pages and SSM
rows advance inside a single lowered scan — ``models.lm.decode_step``);
single-device engines use dynamic-index cache writes and, when greedy,
fuse argmax into the step so only (B,) token ids cross the host boundary.
The dense path keeps the legacy synchronous admission (its slot-insert is
exact-output-critical).

Serving variants come from a ``VariantTable`` (the explorer's serving grid):
every variant's decode executable is registered up front and the active one
is swapped at a step boundary — an O(µs) dictionary lookup, the DynamoRIO
function-pointer swap analogue. When a ``PliantRuntime`` is attached, the
engine feeds per-token latency to its ``LatencyMonitor``, ticks the arbiter
at step boundaries, and receives its decisions back through the tenant
protocol (``request_variant`` — deferred while an admission is in flight),
converting cache dtype when a swap crosses the ``kv_quant`` boundary. A
multi-tenant runtime (``launch/colocate.py``) attaches the same way via
``attach_runtime``. Under a mesh, params shard via
``dist.param_shardings`` and caches via ``dist.cache_shardings``.
"""
from __future__ import annotations

import collections
import contextlib
import functools
import threading
import time
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.knobs import ApproxKnobs, PRECISE
from repro.configs.base import LOCAL_ATTN, MAMBA, ModelConfig, ShapeConfig
from repro.core import tenant as tenant_mod
from repro.core.runtime import PliantRuntime
from repro.core.variants import VariantTable
from repro.dist import elastic
from repro.models import lm
from repro.models.attention import PagedKVCache
from repro.models.mamba2 import MambaCache
from repro.serve import pages as pages_mod
from repro.serve import slots as slots_mod
from repro.train import step as step_mod


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False
    t_arrival: float = 0.0    # driver-set (open-loop client)
    t_enqueue: float = 0.0    # stamped by submit(): admission-timeout clock
    t_admit_start: float = 0.0  # first prefill chunk issued (queue-wait ends)
    t_admit: float = 0.0      # admission COMPLETION (prefill done, slot live)
    admit_compute_s: float = 0.0  # pure prefill executable time (no queueing,
                                  # no interleaved decode steps)
    token_times: List[float] = field(default_factory=list)
    rejected: bool = False    # structured rejection (never silently dropped)
    rejection: Optional["AdmissionTimeout"] = None


@dataclass(frozen=True)
class AdmissionTimeout:
    """Structured admission rejection: the request waited in the queue past
    the engine's ``admission_timeout_s`` bound without ever fitting the pool.
    Attached to ``Request.rejection``, collected on ``engine.rejected``, and
    counted in ``engine.stats`` — a rejection is an explicit, attributable
    outcome, never a request that silently vanished under pressure."""
    uid: int
    waited_s: float
    queue_depth: int       # pending queue length at rejection time
    step: int              # engine step at which the timeout fired


@dataclass
class _Admission:
    """One in-flight background admission (continuous-batching loop): the
    prompt's prefill progress, advanced chunk-by-chunk under the per-step
    QoS budget. Several may be in flight at once — one per free slot."""
    req: Request
    slot: int
    next: int                    # next prompt index to prefill
    stops: List[int]             # ascending pause points; last == len(prompt)
    mamba_register: List[int]    # boundaries registered WITH an SSM snapshot
    tail_register: List[int]     # boundaries registered after completion
    logits: object = None
    compute_s: float = 0.0
    started: bool = False        # first chunk issued (queue-wait ends THEN,
                                 # not when the admission is opened)


@dataclass
class ServeEngine:
    cfg: ModelConfig
    batch_slots: int
    max_len: int
    knobs: ApproxKnobs = PRECISE       # single-variant mode (no table)
    temperature: float = 0.0           # 0.0 = greedy
    params: object = None
    table: Optional[VariantTable] = None
    runtime: Optional[PliantRuntime] = None
    mesh: object = None
    policy: str = "tp"                 # param sharding policy under a mesh
    prefill_chunk: int = 16
    seed: int = 0
    cache_dtype: object = jnp.float32
    paged: bool = False                # paged pool instead of dense rings
    page_size: int = 8
    n_pages: int = 0                   # 0 = auto (serve.pages.spec_for)
    use_kernel: Optional[bool] = None  # paged-attention dispatch override
                                       # (None = fused kernel on TPU only)
    kernel_interpret: bool = False     # Pallas interpret mode (CPU CI of the
                                       # sharded kernel path)
    max_prefill_exes: int = 16         # LRU bound on admission executables
    pack_window: int = 4               # pending requests scanned per step for
                                       # page-aware packing (bounds host work
                                       # while the pool is blocked)
    max_head_skips: int = 64           # packing fairness: after this many
                                       # head-of-queue skips, admit strict
                                       # FIFO so a large request cannot be
                                       # starved by a stream of small ones
    max_admission_chunks: int = 4      # prefill-chunk burst per step when no
                                       # decoder needs protecting (or QoS
                                       # headroom says bursting is safe)
    qos_guard: float = 0.25            # guard band: burst only while monitor
                                       # p99 <= (1 - guard) * QoS target
    admission_timeout_s: float = 0.0   # 0 = wait forever; > 0 = reject a
                                       # never-admitted request after this
                                       # long with a structured
                                       # AdmissionTimeout (engine.rejected)
    backoff_base: int = 1              # steps before retrying a pool-blocked
    backoff_cap: int = 8               # request; doubles per failure, capped
    background_compile: bool = True    # AOT-compile surviving-mesh decode
                                       # during a revocation's grace window
    megastep_k: int = 0                # > 0: fuse up to K decode steps per
                                       # dispatch (lax.scan megastep with
                                       # on-device sampling/stop masking +
                                       # async double-buffered host loop);
                                       # paged engines only. 0 = per-step
    eos_id: int = -1                   # stop-token id (-1 = none): a row
                                       # emitting it finishes early, on
                                       # device mid-megastep or on host in
                                       # the per-step path — same contract
    sync_timing: bool = False          # drain each megastep before
                                       # dispatching the next: no pipeline
                                       # overlap, but per-token stamps
                                       # measure compute, not enqueue
                                       # (benchmarks set this)
    donate: bool = True                # donate cache buffers into decode /
                                       # megastep / admission executables
                                       # (in-place pool + SSM update — no
                                       # per-step full-cache copy); rebuilt
                                       # executables re-donate after an
                                       # elastic re-home or variant swap

    def __post_init__(self):
        if self.runtime is not None:
            self.table = self.runtime.table
        self._variant_knobs = ([v.knobs for v in self.table.variants]
                               if self.table is not None else [self.knobs])
        self._active = 0
        self.pool: Optional[pages_mod.PagePool] = None
        self._page_spec = None
        self.stores: List[pages_mod.CacheStore] = []
        # greedy paged engines fuse argmax into the decode executable: the
        # step returns (B,) token ids, so the host never pulls (B, V) logits
        self._fused_sample = bool(self.paged and self.temperature <= 0.0)
        self._derive_plans()
        if self.paged:
            self._page_spec = pages_mod.spec_for(
                self.batch_slots, self.max_len, self.page_size, self.n_pages,
                n_shards=self._plan_shards())
            self.pool = pages_mod.PagePool(self._page_spec, self.batch_slots)
            # one store per cache kind behind the shared CacheStore protocol:
            # the page pool for attention state, the trivial per-slot store
            # for SSM state — the engine frees every kind uniformly
            self.stores = [self.pool]
            if MAMBA in self.cfg.pattern:
                self.stores.append(pages_mod.MambaSlotStore())
        self._derive_shardings()
        if self._param_sh is not None:
            with self._ctx():
                self.params = jax.device_put(self.params, self._param_sh)

        # the variant table of decode executables: registered once up front,
        # hot-swapped between steps (no recompilation on the critical path).
        # Engine-owned, never written into the (possibly shared) table —
        # executables are lowered against THIS engine's mesh/shardings.
        # Paged engines take the per-slot ``active`` write mask so decode
        # can interleave with background admission (stall-free loop). Under
        # a mesh the fused kernel runs shard_map'd over the slot-affinity
        # pool when the decode plan allows; otherwise the attention layer
        # takes the GSPMD gather path and logs why (attention.explain_
        # dispatch reports the decision up front).
        self._decodes: Dict[int, object] = {
            i: None for i in range(len(self._variant_knobs))}
        self._build_decodes()
        # admission executables, keyed by (knobs, chunk len, paged) — NOT by
        # variant index, so table entries with identical admission knobs
        # share one compiled chunk cell — and LRU-bounded
        self._prefills: "collections.OrderedDict[Tuple, object]" = \
            collections.OrderedDict()
        self._insert = jax.jit(slots_mod.insert_request)

        self.caches = self._init_caches(self.active_knobs.kv_quant)
        self.positions = np.zeros(self.batch_slots, np.int32)
        self.slots: List[Optional[Request]] = [None] * self.batch_slots
        self.pending: Deque[Request] = collections.deque()
        # in-flight background admissions, keyed by slot (insertion order =
        # admission order): continuous batching keeps one per free slot
        self._admissions: Dict[int, _Admission] = {}
        # admissions whose LAST chunk is dispatched but not yet drained:
        # first-token sampling waits for the step's single drain point so
        # the final chunk's compute overlaps the decode dispatched after it
        self._await_admit: Dict[int, _Admission] = {}
        # ---- megastep pipeline state (megastep_k > 0) ----
        if self.megastep_k:
            assert self.paged, "megastep decode requires the paged engine"
        self._megasteps: Dict[Tuple[int, int], object] = {}  # (variant, k)
        self._inflight: Optional[dict] = None  # dispatched, undrained round
        self._carry = None             # device (cur, pos, alive, draws,
                                       # budget) chained between dispatches;
                                       # None = cold-start from host mirrors
        self._inject_slots: Set[int] = set()   # slots (re)activated since
                                               # the last dispatch: their
                                               # carry rows merge from host
        self._uids = np.zeros(self.batch_slots, np.int32)  # sampler stream
        self._pos_ub = np.zeros(self.batch_slots, np.int32)  # exclusive ub
                                       # on positions in-flight megasteps
                                       # may write (page pre-map horizon)
        self.decode_dispatches = 0     # decode/megastep executable calls
        self.row_dispatches = 0        # per-row dispatch count: a row in a
        self.row_tokens = 0            # drain with n>=1 tokens adds (1, n)
                                       # — dispatches/token = 1.0 per-step,
                                       # ~1/K under a sustained megastep
        self.drain_block_s = 0.0       # wall spent blocked at drain points
        self._head_skips = 0           # consecutive pool-blocked head-of-queue
        # window-exit page freeing is sound only when EVERY attention layer
        # is banded (a single global/shared layer still reaches every page)
        self._window_free = (self.cfg.window if self.paged and self.cfg.window
                             and set(self.cfg.pattern) <= {LOCAL_ATTN, MAMBA}
                             else 0)
        self.cur_tokens = np.zeros(self.batch_slots, np.int32)
        self.step_latencies: List[float] = []
        self.admit_latencies: List[float] = []
        self.swaps: List[Tuple[int, int]] = []   # (step index, variant index)
        self.step_admission_chunks: List[Tuple[int, int]] = []  # (used, budget)
        self._token_lat: List[float] = []        # unflushed monitor samples
        # per-request PRNG streams keyed (engine seed, uid): sampling is
        # invariant to slot assignment and admission interleaving, so
        # continuous batching reproduces the wave-scheduled token streams
        self._rngs: Dict[int, np.random.Generator] = {}
        self._pending_variant: Optional[int] = None
        # ---- elasticity / fault state (dist.elastic) ----
        self.step_count = 0
        self._base_mesh = self.mesh          # full-capacity mesh (restore)
        self._revoked: Set[int] = set()      # device ids currently revoked
        self._pending_capacity: List[Tuple[int, object]] = []  # (due, event)
        self._collective_failures = 0        # queued transient step failures
        self._recovering: List[dict] = []    # rehome entries awaiting first
                                             # completed decode step
        self.elastic_log: List[dict] = []
        self._prepared: Dict[Tuple, object] = {}   # AOT-compiled decodes for
        self._compile_threads: List[threading.Thread] = []  # a pending mesh
        # admission backoff/timeout state
        self._backoff: Dict[int, Tuple[int, int]] = {}  # uid -> (retry, dly)
        self.rejected: List[Request] = []
        self.stats: Dict[str, int] = dict(
            admission_timeouts=0, backoff_skips=0, collective_retries=0,
            capacity_events=0, rehomes=0)
        self._tenant = None
        self._bound = False
        if (self.runtime is not None and self.runtime.auto_tenant
                and self.runtime.reshard_fn is None):
            # bind this engine as the runtime's tenant (replacing the
            # constructor's placeholder wrap — unless the caller supplied
            # their own reshard actuator, which stays in charge of quanta):
            # variant hot-swaps arrive via ``request_variant`` and — for
            # paged engines — pool_pages is the tenant's reclaimable quanta
            # (RECLAIM shrinks the page budget, prefix cache evicted first;
            # RETURN grows it back)
            self._tenant = tenant_mod.ServeTenant(engine=self)
            self.runtime.bind(self._tenant)
            self._bound = True

    # ------------------------------------------------------------- layout --
    # Every mesh-dependent decision is (re)derived by the helpers below —
    # at construction AND again by ``_rehome`` when a capacity event changes
    # the mesh. Nothing about the layout is cached anywhere else.

    def _derive_plans(self) -> None:
        """Slot-affinity decode plan + ring-prefill sequence plan, decided
        from (cfg, CURRENT mesh, slots/chunk) by the pure plan functions the
        traced steps re-derive — no side channel."""
        self._decode_plan, self._plan_reason = None, "single device"
        self._prefill_plan, self._prefill_reason = None, "single device"
        if self.mesh is None:
            return
        from repro.dist import sharding as dist_sharding
        if self.paged:
            self._decode_plan, self._plan_reason = \
                dist_sharding.paged_decode_plan(
                    self.cfg, self.mesh, self.batch_slots, self.n_pages)
        self._prefill_plan, self._prefill_reason = \
            dist_sharding.prefill_plan(self.cfg, self.mesh,
                                       self.prefill_chunk)

    def _plan_shards(self) -> int:
        return (self._decode_plan.n_shards
                if self._decode_plan is not None else 1)

    def _derive_shardings(self) -> None:
        self._param_sh = self._cache_sh = None
        if self.mesh is None:
            return
        from repro.dist import sharding as dist_sharding
        self._param_sh = dist_sharding.param_shardings(
            self.cfg, self.mesh, self.policy)
        shp = ShapeConfig("serve", self.max_len, self.batch_slots, "decode")
        self._cache_sh, _ = dist_sharding.cache_shardings(
            self.cfg, shp, self.mesh, paged=self._page_spec)

    def _decode_builder(self):
        if self.paged:
            return functools.partial(
                step_mod.make_paged_serve_step,
                mesh=self.mesh,
                use_kernel=self.use_kernel,
                interpret=self.kernel_interpret,
                dynamic_scatter=self.mesh is None,
                sample_greedy=self._fused_sample)
        return step_mod.make_serve_step

    def _mesh_key(self, mesh) -> Tuple:
        if mesh is None:
            return ("1x1",)
        return (tuple(sorted(mesh.shape.items())),
                tuple(int(d.id) for d in np.asarray(mesh.devices).ravel()))

    def _build_decodes(self) -> None:
        """(Re)lower the decode executable of every REGISTERED variant
        against the current mesh/shardings (retired variants stay retired).
        jit is lazy, so rebuilding the whole dict costs wrapper setup only —
        compilation happens at each variant's first post-(re)build call,
        except where ``_prepared`` holds an AOT executable background-
        compiled during a revocation grace window."""
        mk = self._decode_builder()
        mkey = self._mesh_key(self.mesh)
        prepared = getattr(self, "_prepared", {})   # post-init ordering
        self._decodes = {
            i: (prepared.pop((mkey, i), None)
                or self._lower_decode(mk(self.cfg, self._variant_knobs[i])))
            for i in self._decodes}

    # ----------------------------------------------------------- dispatch --

    @property
    def sharded_kernel(self) -> bool:
        """True when this engine's decode executable runs the fused kernel
        shard_map'd over the slot-affinity pool (the multi-device fast
        path), False for single-device kernels and gather fallbacks."""
        if not self.paged or self._decode_plan is None:
            return False
        if self.use_kernel is not None:
            return bool(self.use_kernel)
        from repro.kernels import ops as kops
        return kops._on_tpu()

    def explain_dispatch(self) -> str:
        """One-line paged-decode dispatch description (startup banner)."""
        from repro.models import attention as attn_mod
        if not self.paged:
            return "dense decode: ring caches (no paged dispatch)"
        return attn_mod.explain_dispatch(
            self.cfg, self.mesh, batch_slots=self.batch_slots,
            n_pages=self._page_spec.n_pages, use_kernel=self.use_kernel,
            megastep_k=self.megastep_k if self.paged else 0)

    def explain_megastep(self) -> str:
        """One-line megastep/pipeline description (startup banner)."""
        if not self.paged or self.megastep_k <= 0:
            return "megastep: off (one decode dispatch per token)"
        samp = ("greedy argmax" if self.temperature <= 0.0 else
                f"temperature categorical, (seed,uid,draw) fold-in "
                f"seed={self.seed}")
        return (f"megastep: up to {self.megastep_k} tokens fused per "
                f"dispatch (lax.scan), on-device {samp} + EOS/budget stop "
                f"masking, cache donation {'ON' if self.donate else 'OFF'}, "
                + ("sync-timing drain (no overlap)" if self.sync_timing
                   else "async double-buffered host pipeline"))

    @property
    def sharded_prefill(self) -> bool:
        """True when this engine's admission chunks run the ring-attention
        sequence-parallel cell (full-size chunks; ragged tails re-plan)."""
        if self._prefill_plan is None:
            return False
        if self.use_kernel is not None:
            return bool(self.use_kernel)
        from repro.kernels import ops as kops
        return kops._on_tpu()

    def explain_prefill_dispatch(self) -> str:
        """One-line chunked-prefill dispatch description (startup banner)."""
        from repro.models import attention as attn_mod
        return attn_mod.explain_prefill_dispatch(
            self.cfg, self.mesh, chunk_len=self.prefill_chunk,
            use_kernel=self.use_kernel)

    # ------------------------------------------------------------ variants --

    @property
    def active_variant(self) -> int:
        return self._active

    @property
    def active_knobs(self) -> ApproxKnobs:
        return self._variant_knobs[self._active]

    def set_variant(self, idx: int) -> None:
        """Hot-swap the decode executable at a step boundary, converting the
        KV rings/pages when the swap crosses the ``kv_quant`` boundary."""
        if idx == self._active:
            return
        old, new = self.active_knobs, self._variant_knobs[idx]
        if old.kv_quant != new.kv_quant:
            with self._ctx():
                self.caches = slots_mod.convert_caches(
                    self.caches, new.kv_quant, self.cache_dtype)
                if self._cache_sh is not None:
                    self.caches = jax.device_put(self.caches, self._cache_sh)
        if self.pool is not None and old != new:
            # prefix entries are tagged by the knobs that computed them; a
            # swap re-encodes the pool in place, so drop the stale index
            self.pool.flush_prefixes()
        self._active = idx
        self.swaps.append((len(self.step_latencies), idx))

    def request_variant(self, idx: int) -> None:
        """Tenant-protocol actuation: hot-swap at the next SAFE step
        boundary. Swaps are deferred while an admission is in flight — a
        mid-prompt knob change would mix admission executables (and prefix
        tags) within one request."""
        self._pending_variant = idx
        self._apply_pending_variant()

    def _apply_pending_variant(self) -> None:
        # undrained admissions (_await_admit) count as in flight: their
        # prefix tags / logits came from the old knobs
        if (self._pending_variant is None or self._admissions
                or self._await_admit):
            return
        idx, self._pending_variant = self._pending_variant, None
        if idx != self._active:
            self.set_variant(idx)

    def attach_runtime(self, runtime: PliantRuntime,
                       tenant=None) -> None:
        """Attach a pre-built (multi-tenant) runtime AFTER construction —
        the colocate harness builds engine -> ServeTenant -> runtime in
        that order. The engine then drives the control loop (latency feed
        + decision ticks at its step boundaries); actuation arrives back
        through ``tenant`` (this engine's adapter in the runtime's list,
        located automatically when omitted). A multi-tenant runtime MUST
        contain this engine's adapter: the unbound fallback polls
        ``states[0]``, which would apply ANOTHER tenant's variant index to
        this engine."""
        if tenant is None:
            tenant = next((t for t in runtime.tenants
                           if isinstance(t, tenant_mod.ServeTenant)
                           and t.engine is self), None)
        assert tenant is not None or len(runtime.tenants) == 1, \
            "multi-tenant runtime has no ServeTenant for this engine"
        self.runtime = runtime
        self._tenant = tenant
        self._bound = tenant is not None

    def retire_variant(self, idx: int) -> None:
        """Drop a retired table entry's executables. Admission cells are
        knobs-keyed, so they survive while any live variant shares the
        knobs and are evicted with the last user."""
        assert idx != self._active, "cannot retire the active variant"
        self._decodes.pop(idx, None)
        kn = self._variant_knobs[idx]
        if any(k == kn for i, k in enumerate(self._variant_knobs)
               if i != idx and i in self._decodes):
            return
        for key in [k for k in self._prefills if k[0] == kn]:
            del self._prefills[key]

    def _lower_decode(self, step):
        # donate the caches argument: the pool/SSM state updates in place
        # instead of being copied whole per step (the dominant decode HBM
        # cost at high occupancy). Donation is an executable property, so a
        # rebuild (_rehome, variant swap) re-donates automatically; the
        # collective-failure retry path copies first (_call_decode)
        cidx = 4 if self.paged else 3
        kw = dict(donate_argnums=(cidx,)) if self.donate else {}
        if self.mesh is None:
            return jax.jit(step, **kw)
        if self.paged:      # (params, tokens, position, active, caches)
            return jax.jit(step,
                           in_shardings=(self._param_sh, None, None, None,
                                         self._cache_sh),
                           out_shardings=(None, self._cache_sh), **kw)
        return jax.jit(step,
                       in_shardings=(self._param_sh, None, None,
                                     self._cache_sh),
                       out_shardings=(None, self._cache_sh), **kw)

    def _prefill_exe(self, chunk_len: int):
        key = (self.active_knobs, chunk_len, self.paged)
        fn = self._prefills.get(key)
        if fn is not None:
            self._prefills.move_to_end(key)
            return fn
        # the caches argument (position 3 in both admission signatures)
        # donates like the decode path: a chunked prefill updates the pool /
        # fresh single-request cache in place instead of copying it per chunk
        kw = dict(donate_argnums=(3,)) if self.donate else {}
        if self.paged:
            step = step_mod.make_paged_admission_step(
                self.cfg, self.active_knobs,
                dynamic_scatter=self.mesh is None, mesh=self.mesh,
                use_kernel=self.use_kernel, interpret=self.kernel_interpret)
            if self.mesh is None:
                fn = jax.jit(step, **kw)
            else:
                fn = jax.jit(step,
                             in_shardings=(self._param_sh, None, None,
                                           self._cache_sh, None),
                             out_shardings=(None, self._cache_sh), **kw)
        else:
            step = step_mod.make_admission_step(
                self.cfg, self.active_knobs, mesh=self.mesh,
                use_kernel=self.use_kernel, interpret=self.kernel_interpret)
            if self.mesh is None:
                fn = jax.jit(step, **kw)
            else:
                fn = jax.jit(step, in_shardings=(self._param_sh, None, None,
                                                 None), **kw)
        self._prefills[key] = fn
        while len(self._prefills) > self.max_prefill_exes:
            self._prefills.popitem(last=False)
        return fn

    # ------------------------------------------------------------- helpers --

    def _ctx(self):
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.dist import compat
        return compat.set_mesh(self.mesh)

    def _init_caches(self, quantized: bool):
        if self.paged:
            sp = self._page_spec
            caches = lm.init_paged_caches(
                self.cfg, self.batch_slots, sp.n_pages, sp.page_size,
                sp.max_pages, dtype=self.cache_dtype, quantized=quantized)
        else:
            caches = lm.init_caches(self.cfg, self.batch_slots, self.max_len,
                                    dtype=self.cache_dtype,
                                    quantized=quantized)
        if self._cache_sh is not None:
            with self._ctx():
                caches = jax.device_put(caches, self._cache_sh)
        return caches

    def _rng_for(self, req: Request) -> np.random.Generator:
        g = self._rngs.get(req.uid)
        if g is None:
            g = np.random.default_rng((self.seed, req.uid))
            self._rngs[req.uid] = g
        return g

    def _sample_rows(self, logits: np.ndarray,
                     reqs: List[Request]) -> np.ndarray:
        """ONE batched sampling call for every emitting row (the per-row
        numpy loop cost O(slots) softmax passes per step). logits: (R, V);
        ``reqs`` the emitting requests, row-aligned. Greedy is a single
        argmax; temperature sampling draws one uniform per request from its
        PRIVATE stream and inverts the softmax CDF — exactly the tokens a
        per-row loop over the same streams would produce, regardless of
        which rows happen to share the batch."""
        if self.temperature <= 0.0:
            return np.argmax(logits, axis=-1)
        z = logits.astype(np.float64) / self.temperature
        z -= z.max(axis=-1, keepdims=True)
        p = np.exp(z)
        cdf = np.cumsum(p, axis=-1)
        u = np.asarray([self._rng_for(r).random() for r in reqs])
        idx = (cdf < u[:, None] * cdf[:, -1:]).sum(axis=-1)
        return np.minimum(idx, logits.shape[-1] - 1)

    def submit(self, req: Request) -> None:
        req.t_enqueue = req.t_enqueue or time.perf_counter()
        self.pending.append(req)

    # ---------------------------------------------------------- elasticity --

    def inject(self, ev, *, notify_runtime: bool = True) -> None:
        """Entry point for a ``dist.elastic.CapacityEvent`` (fault injector,
        driver, or tenant adapter). A revocation with a grace deadline is
        deferred to ``step + deadline_steps``: through the grace window the
        engine keeps serving on the doomed mesh while the runtime — notified
        here — treats the pending loss as contention (the variant ladder
        degrades through the normal Fig. 3 loop instead of traffic being
        rejected) and the surviving-mesh executables start compiling in the
        background. Everything else applies at the next step boundary.
        ``notify_runtime=False`` is for tenant adapters whose runtime
        already saw the event (``PliantRuntime.inject`` fans out both
        ways)."""
        self.stats["capacity_events"] += 1
        if notify_runtime and self.runtime is not None:
            self.runtime.notify_capacity(ev)
        due = self.step_count
        if ev.kind == elastic.REVOKE and ev.deadline_steps > 0:
            due += ev.deadline_steps
            self.elastic_log.append(dict(
                step=self.step_count, kind="revoke_notice", count=ev.count,
                devices=list(ev.devices), deadline_step=due))
            if self.background_compile and self.paged \
                    and self._base_mesh is not None:
                self._precompile_async(ev)
        self._pending_capacity.append((due, ev))

    def _process_capacity(self) -> None:
        """Apply every capacity event whose (grace) deadline has arrived —
        called at the top of ``step()``, so cutovers happen at step
        boundaries only."""
        if not self._pending_capacity:
            return
        due = [e for s, e in self._pending_capacity if s <= self.step_count]
        self._pending_capacity = [(s, e) for s, e in self._pending_capacity
                                  if s > self.step_count]
        for ev in due:
            self._apply_capacity(ev)

    def _apply_capacity(self, ev) -> None:
        entry = dict(step=self.step_count, kind=ev.kind)
        if ev.kind in (elastic.REVOKE, elastic.RESTORE):
            if self._base_mesh is None:
                # single-device engine: no mesh to shrink — the event still
                # flowed to the runtime as pressure, which is all it can mean
                entry["ignored"] = "no mesh"
                self.elastic_log.append(entry)
                return
            if ev.kind == elastic.REVOKE:
                ids = ev.devices or elastic.pick_revoked(
                    self.mesh if self.mesh is not None else self._base_mesh,
                    ev.count, already=self._revoked)
                self._revoked |= {int(i) for i in ids}
            else:
                self._revoked -= ({int(i) for i in ev.devices}
                                  if ev.devices else set(self._revoked))
            new_mesh, why = elastic.surviving_mesh(
                self._base_mesh, self._revoked,
                prefer_divisor_of=self.batch_slots)
            entry.update(self._rehome(new_mesh, why))
            entry["revoked"] = sorted(self._revoked)
            self._recovering.append(entry)
        elif ev.kind == elastic.QUOTA_CUT:
            if self.pool is not None:
                self.pool.set_capacity_cut(self.pool.capacity_cut + ev.quanta)
                entry["capacity_cut"] = self.pool.capacity_cut
        elif ev.kind == elastic.QUOTA_RESTORE:
            if self.pool is not None:
                cut = (self.pool.capacity_cut - ev.quanta if ev.quanta else 0)
                self.pool.set_capacity_cut(max(cut, 0))
                entry["capacity_cut"] = self.pool.capacity_cut
        elif ev.kind == elastic.COLLECTIVE_FAILURE:
            self._collective_failures += max(ev.count, 1)
            entry["queued_failures"] = self._collective_failures
        self.elastic_log.append(entry)

    def _rehome(self, new_mesh, why: str = "") -> dict:
        """Cut the LIVE engine over to ``new_mesh`` (shrink on revocation,
        grow on restore) without dropping anything. All durable decode state
        is mesh-shape-independent — (pool, caches, positions, cur_tokens,
        admission chunk cursors) — only WHERE the arrays live changes:

        1. re-derive the layout plans/shardings for the new mesh (the same
           pure functions construction uses; an infeasible plan degrades
           loudly to the gather/unsharded path, it never corrupts);
        2. migrate the page pool (``PagePool.migrate``: live pages re-homed
           onto their slots' new affinity shards, prefix entries evicted)
           and permute the host-staged device caches to match;
        3. re-put params under the new shardings (host-staged — the revoked
           devices may be gone);
        4. rebuild the decode executables (AOT background-compiled ones are
           picked up when ready; the rest compile lazily at first call) and
           drop the admission-cell LRU — in-flight ``_Admission``s simply
           resume at their chunk cursor on the new mesh."""
        t0 = time.perf_counter()
        # flush the async pipeline first: the in-flight megastep's tokens
        # must land (and its donated-cache chain settle) before the caches
        # are host-staged; the device carry is invalidated — the first
        # dispatch on the new mesh cold-starts from the host mirrors
        self._drain_pipeline()
        # in-flight admission logits live on the old mesh — host-stage them
        # (drain-deferred completions in _await_admit included)
        for adm in list(self._admissions.values()) \
                + list(self._await_admit.values()):
            if adm.logits is not None:
                adm.logits = np.asarray(adm.logits)
        old_shards = self._plan_shards() if self.paged else 1
        self.mesh = new_mesh
        self._derive_plans()
        migrated = 0
        if self.paged:
            new_spec = pages_mod.spec_for(
                self.batch_slots, self.max_len, self.page_size, self.n_pages,
                n_shards=self._plan_shards())
            new_pool, perm = self.pool.migrate(new_spec)
            self._page_spec = new_spec
            self._derive_shardings()
            self.caches = self._migrate_paged_caches(perm, new_pool)
            self.pool = new_pool
            self.stores[0] = new_pool
            migrated = int((perm >= 0).sum())
        else:
            self._derive_shardings()
            with self._ctx():
                self.caches = elastic.reshard_live(self.caches,
                                                   self._cache_sh)
        with self._ctx():
            self.params = elastic.reshard_live(self.params, self._param_sh)
        self._build_decodes()
        self._prefills.clear()
        self._megasteps.clear()    # lowered against the old mesh/shardings;
                                   # rebuilt (and re-donated) lazily
        self.stats["rehomes"] += 1
        return dict(
            step_index=len(self.step_latencies), why=why,
            mesh_shape=(dict(new_mesh.shape) if new_mesh is not None
                        else None),
            n_shards=(old_shards, self._plan_shards() if self.paged else 1),
            pages_migrated=migrated,
            cutover_s=time.perf_counter() - t0,
            recovery_steps=None, _t_rehome=t0)

    def _migrate_paged_caches(self, perm: np.ndarray, new_pool):
        """Host-stage the old device caches and permute the physical-page
        axis into the new pool's layout: ``perm[new_pid] = old_pid`` source
        (-1 = starts empty — zero KV, -1 positions, masked out of
        attention). Leaves are group-stacked, so the page dim is axis 1;
        Mamba rows are slot-major and pass through unchanged. The staged
        copy is the only surviving reference once the old devices go."""
        dst = np.flatnonzero(perm >= 0)
        src = perm[dst]
        bt = np.asarray(new_pool.blocks)

        def move(x, fill):
            x = np.asarray(jax.device_get(x))
            out = np.full((x.shape[0], new_pool.spec.n_pages) + x.shape[2:],
                          fill, x.dtype)
            out[:, dst] = x[:, src]
            return out

        caches = []
        for c in self.caches:
            if isinstance(c, PagedKVCache):
                caches.append(PagedKVCache(
                    kp=move(c.kp, 0), vp=move(c.vp, 0),
                    ppos=move(c.ppos, -1),
                    block=np.broadcast_to(
                        bt[None], (np.shape(c.block)[0],) + bt.shape).copy()))
            else:
                caches.append(elastic.host_stage(c))
        caches = tuple(caches)
        with self._ctx():
            if self._cache_sh is not None:
                return jax.device_put(caches, self._cache_sh)
            return jax.tree.map(jnp.asarray, caches,
                                is_leaf=lambda x: isinstance(x, np.ndarray))

    def _precompile_async(self, ev) -> None:
        """Best-effort AOT compile of the ACTIVE variant's decode executable
        for the mesh that survives ``ev``, on a background thread during the
        revocation grace window — the cutover's first step then skips the
        full compile. Any failure just falls back to lazy compilation at
        cutover; correctness never depends on this racing to finish."""
        lost = self._revoked | set(ev.devices or elastic.pick_revoked(
            self.mesh if self.mesh is not None else self._base_mesh,
            ev.count, already=self._revoked))
        new_mesh, _ = elastic.surviving_mesh(
            self._base_mesh, lost, prefer_divisor_of=self.batch_slots)
        if new_mesh is None:
            return
        variant = self._active
        key = (self._mesh_key(new_mesh), variant)
        if key in self._prepared:
            return

        def compile_target():
            try:
                from repro.dist import sharding as dist_sharding
                plan, _ = dist_sharding.paged_decode_plan(
                    self.cfg, new_mesh, self.batch_slots, self.n_pages)
                spec = pages_mod.spec_for(
                    self.batch_slots, self.max_len, self.page_size,
                    self.n_pages,
                    n_shards=plan.n_shards if plan is not None else 1)
                psh = dist_sharding.param_shardings(self.cfg, new_mesh,
                                                    self.policy)
                shp = ShapeConfig("serve", self.max_len, self.batch_slots,
                                  "decode")
                csh, _ = dist_sharding.cache_shardings(
                    self.cfg, shp, new_mesh, paged=spec)
                step = step_mod.make_paged_serve_step(
                    self.cfg, self._variant_knobs[variant], mesh=new_mesh,
                    use_kernel=self.use_kernel,
                    interpret=self.kernel_interpret, dynamic_scatter=False,
                    sample_greedy=self._fused_sample)
                sds = lambda t: jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(np.shape(x),
                                                   np.asarray(x).dtype
                                                   if not hasattr(x, "dtype")
                                                   else x.dtype), t)
                caches_abs = jax.eval_shape(functools.partial(
                    lm.init_paged_caches, self.cfg, self.batch_slots,
                    spec.n_pages, spec.page_size, spec.max_pages,
                    dtype=self.cache_dtype,
                    quantized=self._variant_knobs[variant].kv_quant))
                B = self.batch_slots
                kw = dict(donate_argnums=(4,)) if self.donate else {}
                exe = jax.jit(
                    step, in_shardings=(psh, None, None, None, csh),
                    out_shardings=(None, csh), **kw
                ).lower(
                    sds(self.params),
                    jax.ShapeDtypeStruct((B, 1), jnp.int32),
                    jax.ShapeDtypeStruct((B,), jnp.int32),
                    jax.ShapeDtypeStruct((B,), jnp.bool_),
                    caches_abs,
                ).compile()
                self._prepared[key] = exe
            except Exception as e:     # pragma: no cover - best effort
                self.elastic_log.append(dict(
                    step=self.step_count, kind="precompile_failed",
                    error=repr(e)))

        th = threading.Thread(target=compile_target, daemon=True)
        self._compile_threads.append(th)
        th.start()

    def _expire_pending(self) -> None:
        """Admission-timeout sweep: reject (structured, loud in stats) every
        queued request that has waited past ``admission_timeout_s`` without
        ever being admitted. In-flight admissions are never expired — they
        are making progress by construction (chunked prefill advances every
        budgeted step)."""
        if self.admission_timeout_s <= 0 or not self.pending:
            return
        now = time.perf_counter()
        keep: Deque[Request] = collections.deque()
        for req in self.pending:
            t0 = req.t_enqueue or req.t_arrival
            if t0 and now - t0 > self.admission_timeout_s:
                req.rejected = True
                req.rejection = AdmissionTimeout(
                    uid=req.uid, waited_s=now - t0,
                    queue_depth=len(self.pending), step=self.step_count)
                self.rejected.append(req)
                self.stats["admission_timeouts"] += 1
                self._backoff.pop(req.uid, None)
                self._rngs.pop(req.uid, None)
            else:
                keep.append(req)
        self.pending = keep

    # ------------------------------------------------------ paged plumbing --

    def _free_slot(self, slot: int) -> bool:
        """Release a finished request's cache residency across every store.
        Returns True when device-visible mapping state changed."""
        dirty = False
        for store in self.stores:
            dirty |= store.free_slot(slot)
        return dirty

    def _push_blocks(self) -> None:
        """Mirror the host block tables into the device caches (host-side
        allocation between steps; jitted steps only read the tables) and
        scrub freed pages' stale positions before they can be reused."""
        bt = jnp.asarray(self.pool.blocks)
        scrub = self.pool.drain_scrub()
        pids = jnp.asarray(scrub, jnp.int32) if scrub else None

        def one(c):
            if isinstance(c, PagedKVCache):
                ppos = c.ppos if pids is None else \
                    c.ppos.at[:, pids].set(-1)
                return c._replace(
                    ppos=ppos,
                    block=jnp.broadcast_to(bt[None], c.block.shape))
            return c

        self.caches = tuple(one(c) for c in self.caches)
        if self._cache_sh is not None:
            with self._ctx():
                self.caches = jax.device_put(self.caches, self._cache_sh)

    def _mamba_snapshot(self, slot: int):
        """Host copy of the slot's SSM state rows (prefix-boundary snapshot
        carried by the prefix index; None for attention-only archs)."""
        snap = {}
        for ci, c in enumerate(self.caches):
            if isinstance(c, MambaCache):
                snap[ci] = MambaCache(*(np.asarray(x[:, slot]) for x in c))
        return snap or None

    def _set_mamba_rows(self, slot: int, snap) -> None:
        """Seed the slot's SSM rows for a fresh admission: the prefix-entry
        snapshot on a hit, zeros otherwise — the previous tenant's state must
        never leak into a new request (the dense path gets this for free
        from its fresh single-request cache + insert)."""
        if not any(isinstance(c, MambaCache) for c in self.caches):
            return
        caches = list(self.caches)
        for ci, c in enumerate(self.caches):
            if not isinstance(c, MambaCache):
                continue
            row = snap.get(ci) if snap else None
            caches[ci] = MambaCache(*(
                x.at[:, slot].set(jnp.zeros_like(x[:, slot]) if r is None
                                  else jnp.asarray(r))
                for x, r in zip(c, row or (None,) * len(c))))
        self.caches = tuple(caches)
        if self._cache_sh is not None:
            with self._ctx():
                self.caches = jax.device_put(self.caches, self._cache_sh)

    # ----------------------------------------------------------- admission --

    def _chunked_prefill(self, prompt: List[int]):
        """Dense path: stream the prompt through fixed-size chunks into a
        fresh single-request cache. Returns (last-token logits, caches)."""
        knobs = self.active_knobs
        caches = lm.init_caches(self.cfg, 1, self.max_len,
                                dtype=self.cache_dtype,
                                quantized=knobs.kv_quant)
        toks = np.asarray(prompt, np.int32)
        S, start, logits = len(prompt), 0, None
        with self._ctx():
            while start < S:
                C = min(self.prefill_chunk, S - start)
                logits, caches = self._prefill_exe(C)(
                    self.params, jnp.asarray(toks[None, start:start + C]),
                    jnp.asarray(start, jnp.int32), caches)
                start += C
        return logits, caches

    def _prefix_dedup_wait(self, req: Request, shard: int = 0) -> bool:
        """Cold-start prefix dedup: True when an in-flight admission is
        prefilling a page-aligned prefix this prompt shares and the index
        does not cover it yet. Admitting now would concurrently re-prefill
        (and re-allocate) pages the sibling is about to register — hold the
        request back until the registration lands. Steady state (prefix
        already indexed) never defers, so warm traces keep full admission
        concurrency. Only siblings on the SAME pool shard count: a prefix
        registered on another shard's pages can never be mapped here (slot
        affinity), so waiting on it would be pure latency."""
        P = self.page_size
        cap = min((len(req.prompt) - 1) // P, self.pool.max_register_pages)
        if cap <= 0 or not self._admissions:
            return False
        best = 0
        for adm in self._admissions.values():
            if self.pool.slot_shard(adm.slot) != shard:
                continue
            other = adm.req.prompt
            lim = min(len(req.prompt), len(other), cap * P)
            k = 0
            while k < lim and req.prompt[k] == other[k]:
                k += 1
            best = max(best, (k // P) * P)
        if not best:
            return False
        return self.pool.lookup_prefix(req.prompt, self.active_knobs,
                                       shard)[0] < best

    def _start_admissions(self, count_skips: bool = True) -> None:
        """Open a background admission on EVERY free slot (continuous
        batching — no wave barrier: a slot freed this step refills this
        step). Per slot, pick the first of the leading ``pack_window``
        pending requests whose pages fit the pool budget (page-aware
        packing — a pool-blocked head of queue must not stall admissions
        that fit) and whose shared prefix is not mid-prefill in a sibling
        admission (``_prefix_dedup_wait``). The window bounds the per-step host work while the pool
        is blocked, and after ``max_head_skips`` consecutive head skips
        admission falls back to strict FIFO so a large request cannot be
        starved by a stream of small ones. Maps the block table grouped —
        prompt pages plus projected decode pages in one transaction; prefix
        hits bump refcounts and skip those chunks — and seeds the slot's
        SSM rows; prefill itself is advanced by ``_advance_admissions``.
        Does NOT stamp ``t_admit_start``: queue-wait ends when the first
        chunk RUNS (``_advance_one``), not when the admission is opened."""
        started_any = False
        while self.pending:
            slot = next((i for i in range(self.batch_slots)
                         if self.slots[i] is None
                         and i not in self._admissions
                         and i not in self._await_admit), None)
            if slot is None:
                break
            strict = self._head_skips >= self.max_head_skips
            window = 1 if strict else min(len(self.pending), self.pack_window)
            started = False
            for qi in range(window):
                req = self.pending[qi]
                assert len(req.prompt) <= self.max_len, \
                    (len(req.prompt), self.max_len)
                assert len(req.prompt) + req.max_new <= \
                    self._page_spec.max_pages * self.page_size, \
                    "paged serving does not ring-wrap: need " \
                    "max_len >= prompt + max_new"
                if self._prefix_dedup_wait(req, self.pool.slot_shard(slot)):
                    continue       # sibling is mid-prefill of our prefix
                bo = self._backoff.get(req.uid)
                if bo is not None and self.step_count < bo[0]:
                    # bounded backoff: a pool-blocked request sits out its
                    # (exponentially grown, capped) window instead of
                    # re-running the admit feasibility gate every step
                    self.stats["backoff_skips"] += 1
                    continue
                # grouped/speculative allocation: reserve the decode pages
                # up front (positions S .. S+max_new-2 are written) so the
                # hot loop's ensure_decode_page never allocates. Banded
                # archs skip the reservation — they free window-dead pages
                # to hold occupancy flat, and pre-mapping the whole decode
                # horizon would defeat that
                reserve = 0 if self._window_free else max(req.max_new - 1, 0)
                plan = self.pool.admit(slot, req.prompt, self.active_knobs,
                                       reserve_tokens=reserve)
                if plan is None:
                    delay = (min(bo[1] * 2, self.backoff_cap) if bo
                             else max(self.backoff_base, 1))
                    self._backoff[req.uid] = (self.step_count + delay, delay)
                    if qi == 0 and count_skips:
                        self._head_skips += 1
                    continue                 # over budget: try the next one
                self._backoff.pop(req.uid, None)
                if qi == 0:
                    self._head_skips = 0
                del self.pending[qi]
                snap = plan.entry.mamba if (plan.shared_tokens and plan.entry)\
                    else None
                self._set_mamba_rows(slot, snap)
                has_mamba = any(isinstance(c, MambaCache)
                                for c in self.caches)
                S = len(req.prompt)
                if has_mamba:
                    # prefill pauses at each boundary so its SSM snapshot
                    # matches
                    stops = sorted(set(plan.register) | {S})
                    mamba_reg, tail_reg = list(plan.register), []
                else:
                    # attention-only: pages are position-addressed,
                    # registration is pure bookkeeping — no need to fragment
                    # the chunk stream
                    stops = [S]
                    mamba_reg, tail_reg = [], list(plan.register)
                self._admissions[slot] = _Admission(
                    req, slot, plan.shared_tokens, stops, mamba_reg, tail_reg)
                started = started_any = True
                break
            if not started:
                break       # nothing in the window fits — later slots share
                            # the same pool, so stop scanning this step
        if started_any:
            # ONE block-table push covers every admission opened this call
            self._push_blocks()

    def _chunk_budget(self) -> int:
        """Prefill chunks this step may spend across all in-flight
        admissions — the QoS-aware knob that trades time-to-first-token
        against inter-token latency. No live decoder: burst (nobody's
        inter-token gap to protect). Otherwise one chunk, unless the
        runtime's monitor has a tail estimate comfortably inside the QoS
        target (p99 at most (1 - qos_guard) x target): with that much
        headroom, admissions may burst without endangering the guarantee.
        An abstaining monitor (below min_samples) or no runtime at all
        means no evidence — stay conservative."""
        cap = max(1, self.max_admission_chunks)
        if not any(s is not None for s in self.slots):
            return cap
        from repro.core.controller import headroom_burst
        if headroom_burst(self.runtime, self.qos_guard):
            return cap
        return 1

    def _megastep_budget(self) -> int:
        """Decode tokens the next megastep may fuse — K as a Pliant-visible
        knob, bounded by the same guard band as ``_chunk_budget`` but
        pulling the OTHER way: large K amortizes dispatch overhead
        (throughput), small K keeps admission interleaving fine-grained and
        lets a de-approximation decision (variant swap, reclaim) take
        effect within one token instead of K. With admission work pending
        the megastep shrinks to 1 unless the monitor shows measured
        headroom (``controller.headroom_burst``); with nothing to
        interleave, full K always. Queued work that CANNOT start — every
        slot occupied, nothing in flight — is not admission work: shrinking
        K for it would serialize the whole first wave at K=1 for nothing."""
        cap = max(1, self.megastep_k)
        admitting = bool(self._admissions or self._await_admit)
        can_start = bool(self.pending) and any(
            self.slots[i] is None and i not in self._admissions
            and i not in self._await_admit
            for i in range(self.batch_slots))
        if not (admitting or can_start):
            return cap
        from repro.core.controller import headroom_burst
        if headroom_burst(self.runtime, self.qos_guard):
            return cap
        return 1

    def _advance_admissions(self) -> None:
        """Continuous-batching admission phase of ``step()``: open
        admissions on free slots, then advance the in-flight set round-robin
        one chunk at a time until the step's QoS chunk budget is spent (or
        nothing is left to advance). Completions free their slot mid-phase,
        so the re-scan between passes can immediately refill it — several
        short prompts can admit back-to-back within one step's budget."""
        budget = self._chunk_budget()
        used = 0
        self._start_admissions()
        while used < budget:
            ran = False
            for slot in list(self._admissions):
                if used >= budget:
                    break
                self._advance_one(self._admissions[slot])
                used += 1
                ran = True
            if not ran:
                break
            self._start_admissions(count_skips=False)
        if used or self._admissions:
            self.step_admission_chunks.append((used, budget))

    def _advance_one(self, adm: _Admission) -> None:
        """Run ONE bounded prefill chunk of ``adm``; on the final chunk,
        sample the first token and activate the slot."""
        req = adm.req
        if not adm.started:
            adm.started = True
            req.t_admit_start = time.perf_counter()   # queue-wait ends HERE
        S = len(req.prompt)
        end = next(b for b in adm.stops if b > adm.next)
        C = min(self.prefill_chunk, end - adm.next)
        toks = np.asarray(req.prompt[adm.next:adm.next + C], np.int32)
        t0 = time.perf_counter()
        with self._ctx():
            adm.logits, self.caches = self._prefill_exe(C)(
                self.params, jnp.asarray(toks[None]),
                jnp.asarray(adm.next, jnp.int32), self.caches,
                jnp.asarray(adm.slot, jnp.int32))
        adm.next += C
        # NO per-chunk (or final-chunk) block here: every sync is deferred
        # to the step's single drain point (_drain_admissions), so the final
        # chunk's compute overlaps whatever the step dispatches after it.
        # compute_s so far holds enqueue time only; the drain stamps the
        # actual wait, keeping admit_compute_p95 honest under async dispatch
        adm.compute_s += time.perf_counter() - t0
        if adm.next in adm.mamba_register:
            self.pool.register_prefix(adm.slot, req.prompt,
                                      self.active_knobs, adm.next,
                                      mamba=self._mamba_snapshot(adm.slot))
        if adm.next < S:
            return
        # admission complete: register remaining boundaries (host
        # bookkeeping — needs no device sync) and park the admission at the
        # drain point; first-token sampling and slot activation happen there
        for b in adm.tail_register:
            self.pool.register_prefix(adm.slot, req.prompt,
                                      self.active_knobs, b)
        # lookup caps sharing at len(prompt)-1 tokens, so at least one chunk
        # always ran and produced the sampling logits
        assert adm.logits is not None
        del self._admissions[adm.slot]
        self._await_admit[adm.slot] = adm

    def _drain_admissions(self) -> None:
        """The admission half of the step's single drain point: block on
        each completed admission's final-chunk logits (the wait lands in
        ``admit_compute_s`` — the dispatch loop stamped only enqueue time),
        sample the first token, and hand the slot to the decode batch.
        Newly activated slots join the NEXT dispatch: the per-step path
        captures its row set before decoding, the megastep path merges them
        into the device carry via ``_inject_slots``."""
        if not self._await_admit:
            return
        freed = False
        for slot, adm in list(self._await_admit.items()):
            req = adm.req
            t0 = time.perf_counter()
            logits = np.asarray(adm.logits)          # <- the drain
            dt = time.perf_counter() - t0
            adm.compute_s += dt
            self.drain_block_s += dt
            del self._await_admit[slot]
            tok = int(self._sample_rows(logits, [req])[0])
            now = time.perf_counter()
            self.admit_latencies.append(adm.compute_s)
            self._token_lat.append(now - req.t_admit_start)  # TTFT (wall)
            req.t_admit = now                  # admission COMPLETION
            req.admit_compute_s = adm.compute_s
            req.out.append(tok)
            req.token_times.append(now)
            if len(req.out) >= req.max_new \
                    or (self.eos_id >= 0 and tok == self.eos_id):
                req.done = True                # 1-token request: no slot
                self._rngs.pop(req.uid, None)
                freed |= self._free_slot(slot)
                continue
            self.positions[slot] = len(req.prompt)
            self.cur_tokens[slot] = tok
            self._uids[slot] = req.uid
            self._pos_ub[slot] = len(req.prompt)
            self.slots[slot] = req
            if self.megastep_k:
                self._inject_slots.add(slot)
        if freed:
            self._push_blocks()

    def _admit(self) -> None:
        """Dense path: legacy synchronous admission (full chunked prefill
        into a fresh cache + slot insert inside one step)."""
        for i in range(self.batch_slots):
            while self.slots[i] is None and self.pending:
                req = self.pending[0]
                assert len(req.prompt) <= self.max_len, \
                    (len(req.prompt), self.max_len)
                t0 = time.perf_counter()
                req.t_admit_start = t0
                logits, rcaches = self._chunked_prefill(req.prompt)
                with self._ctx():
                    self.caches = self._insert(self.caches, rcaches, i)
                    if self._cache_sh is not None:
                        self.caches = jax.device_put(self.caches,
                                                     self._cache_sh)
                self.pending.popleft()
                tok = int(self._sample_rows(np.asarray(logits), [req])[0])
                now = time.perf_counter()
                self.admit_latencies.append(now - t0)
                self._token_lat.append(now - t0)   # TTFT sample
                req.t_admit = now                  # admission COMPLETION
                req.admit_compute_s = now - t0     # sync: compute == wall
                req.out.append(tok)
                req.token_times.append(now)
                if len(req.out) >= req.max_new or (
                        self.eos_id >= 0 and tok == self.eos_id):
                    req.done = True                # 1-token request: no slot
                    continue
                self.positions[i] = len(req.prompt)
                self.cur_tokens[i] = tok
                self.slots[i] = req

    # --------------------------------------------------------------- steps --

    def _call_decode(self, exe, args, cache_idx: int):
        """Dispatch a decode/megastep executable with honest collective-
        failure retry under donation: the call CONSUMES the caches argument
        when donation is on, so a queued injected failure snapshots the
        pre-step caches first and the retry re-issues from the snapshot —
        the semantics stay "results discarded uncommitted, step re-run",
        bounded by the injected count."""
        while True:
            retry = self._collective_failures > 0
            if retry and self.donate:
                safe = jax.tree.map(jnp.copy, args[cache_idx])
            out = exe(*args)
            if not retry:
                return out
            self._collective_failures -= 1
            self.stats["collective_retries"] += 1
            if self.donate:
                args = args[:cache_idx] + (safe,) + args[cache_idx + 1:]

    def _megastep_exe(self, k: int):
        """The fused K-step executable for the ACTIVE variant, lowered
        lazily per (variant, K) and cached — the QoS budget only ever picks
        K from {1, megastep_k}, so at most two executables per variant.
        Cleared (and re-donated on rebuild) by ``_rehome``."""
        key = (self._active, k)
        exe = self._megasteps.get(key)
        if exe is not None:
            return exe
        step = step_mod.make_paged_megastep(
            self.cfg, self.active_knobs, k=k, temperature=self.temperature,
            seed=self.seed, eos_id=self.eos_id, mesh=self.mesh,
            use_kernel=self.use_kernel, dynamic_scatter=self.mesh is None,
            interpret=self.kernel_interpret)
        kw = dict(donate_argnums=(7,)) if self.donate else {}
        if self.mesh is None:
            exe = jax.jit(step, **kw)
        else:
            from repro.dist import sharding as dist_sharding
            in_sh, out_sh = dist_sharding.megastep_shardings(
                self._param_sh, self._cache_sh)
            exe = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                          **kw)
        self._megasteps[key] = exe
        return exe

    def _dispatch_megastep(self) -> Optional[dict]:
        """Dispatch ONE fused K-step decode over the live slots without
        waiting on it (async pipeline): pre-map every page the in-scan
        cursor advance can touch, merge newly activated slots into the
        device carry, and return the flight record the drain consumes.

        The carry (cur/pos/alive/draws/budget) chains device-side between
        dispatches — rows die IN-SCAN on EOS/budget, so the device alive
        mask already agrees with the host's post-drain view and only slot
        (re)activations need injecting (``_inject_slots``). Returns None
        when no slot is decoding."""
        rows = [i for i in range(self.batch_slots)
                if self.slots[i] is not None]
        if not rows:
            # nothing alive: the device carry is stale by construction (the
            # next activation cold-starts from the host mirrors) — drop it
            # so idle engines hold no donated-cache chain
            self._carry = None
            return None
        k = self._megastep_budget()
        # never scan past the longest remaining budget: a row with one
        # token left must not pay a K-step full-batch scan
        k = max(1, min(k, max(self.slots[i].max_new - len(self.slots[i].out)
                              for i in rows)))
        dirty = False
        for i in rows:
            req = self.slots[i]
            # exclusive bound on write positions this row can ever need:
            # decode writes KV at S .. S+max_new-2 (the first of max_new
            # tokens was sampled at admission). _pos_ub ratchets forward by
            # k per dispatch — the host's mirror of the in-scan cursor,
            # conservative while a prior megastep is still in flight
            cap = len(req.prompt) + req.max_new - 1
            ub = min(int(self._pos_ub[i]) + k, cap)
            dirty |= self.pool.ensure_decode_range(
                i, int(self.positions[i]), ub)
            self._pos_ub[i] = ub
        if dirty:
            self._push_blocks()
        t0 = time.perf_counter()
        B = self.batch_slots
        alive_host = np.array([s is not None for s in self.slots])
        with self._ctx():
            if self._carry is None:
                # cold start (first dispatch / post-rehome): the host
                # mirrors are authoritative
                draws = jnp.asarray(np.array(
                    [len(self.slots[i].out) if alive_host[i] else 0
                     for i in range(B)], np.int32))
                budget = jnp.asarray(np.array(
                    [self.slots[i].max_new - len(self.slots[i].out)
                     if alive_host[i] else 0 for i in range(B)], np.int32))
                cur = jnp.asarray(self.cur_tokens)
                pos = jnp.asarray(self.positions)
                alive = jnp.asarray(alive_host)
            else:
                cur, pos, alive, draws, budget = self._carry
                if self._inject_slots:
                    m = np.zeros(B, bool)
                    inj_draws = np.zeros(B, np.int32)
                    inj_budget = np.zeros(B, np.int32)
                    for i in self._inject_slots:
                        req = self.slots[i]
                        m[i] = True
                        inj_draws[i] = len(req.out)
                        inj_budget[i] = req.max_new - len(req.out)
                    mj = jnp.asarray(m)
                    cur = jnp.where(mj, jnp.asarray(self.cur_tokens), cur)
                    pos = jnp.where(mj, jnp.asarray(self.positions), pos)
                    alive = jnp.where(mj, True, alive)
                    draws = jnp.where(mj, jnp.asarray(inj_draws), draws)
                    budget = jnp.where(mj, jnp.asarray(inj_budget), budget)
            args = (self.params, cur, pos, alive, jnp.asarray(self._uids),
                    draws, budget, self.caches)
            toks, cur, pos, alive, draws, budget, new_caches = \
                self._call_decode(self._megastep_exe(k), args, 7)
            self.caches = new_caches
            self._carry = (cur, pos, alive, draws, budget)
        self._inject_slots.clear()
        self.decode_dispatches += 1
        return dict(toks=toks, rows=[(i, self.slots[i]) for i in rows],
                    k=k, t0=t0)

    def _drain_megastep(self, flight: dict) -> None:
        """THE decode drain point: one transfer surfaces up to K tokens and
        the stop flags (the -1 sentinel; vocab ids are >= 0) per row.
        Per-token times interpolate linearly across the megastep wall — the
        same per-megastep -> per-token attribution the QoS monitor applies
        (``LatencyMonitor.record_megastep``). Finished rows free their
        slot/pages here; banded archs release window-dead pages."""
        t0 = time.perf_counter()
        toks = np.asarray(flight["toks"])
        now = time.perf_counter()
        self.drain_block_s += now - t0
        wall = now - flight["t0"]
        self.step_latencies.append(wall)
        for entry in self._recovering:
            # recovery = event application -> first COMPLETED megastep on
            # the re-homed mesh (compile time of the cutover included)
            entry["recovery_steps"] = \
                len(self.step_latencies) - entry["step_index"]
            entry["recovery_s"] = now - entry.pop("_t_rehome")
        self._recovering.clear()
        freed = False
        emitted: List[int] = []
        for i, req in flight["rows"]:
            if req.done:
                continue   # died in an earlier flight; this row is all -1
            n = 0
            for t in toks[i]:
                if t < 0:
                    break  # row died in-scan: EOS or budget exhausted
                n += 1
                req.out.append(int(t))
                self.cur_tokens[i] = int(t)
                self.positions[i] += 1
            if n:
                emitted.append(n)
                self.row_dispatches += 1
                self.row_tokens += n
                for j in range(n):
                    req.token_times.append(
                        flight["t0"] + wall * (j + 1) / n)
            if len(req.out) >= req.max_new or (
                    self.eos_id >= 0 and req.out
                    and req.out[-1] == self.eos_id):
                req.done = True
                self.slots[i] = None        # slot freed: continuous batch
                self._rngs.pop(req.uid, None)
                freed |= self._free_slot(i)
            elif self._window_free:
                # banded arch: pages that fell out of every layer's window
                # are dead — return them so long decodes hold occupancy flat
                freed |= self.pool.release_window_pages(
                    i, int(self.positions[i]) - self._window_free)
        if freed:
            self._push_blocks()
        if self.runtime is not None and emitted:
            self.runtime.monitor.record_megastep(wall, emitted)

    def _drain_pipeline(self) -> None:
        """Flush the async double-buffer before state surgery (elastic
        re-home): drain the in-flight megastep so its tokens land and its
        donated-cache chain settles, and invalidate the device carry — the
        next dispatch cold-starts from the host mirrors."""
        if self._inflight is not None:
            self._drain_megastep(self._inflight)
            self._inflight = None
        self._carry = None

    def _megastep_round(self) -> None:
        """One engine step in megastep mode — the async double-buffered
        host pipeline: advance admissions, dispatch megastep N+1, THEN
        drain megastep N (the device never idles waiting for the host to
        process tokens), drain completed admissions, tick control. The ONE
        explicit drain pair (``_drain_megastep`` + ``_drain_admissions``)
        replaces the per-step path's scattered blocking calls.
        ``sync_timing`` drains each dispatch in its own round instead — no
        overlap, but per-token stamps measure compute, not enqueue."""
        prev, self._inflight = self._inflight, None
        self._advance_admissions()
        flight = self._dispatch_megastep()
        if prev is not None:
            self._drain_megastep(prev)    # dispatch order == drain order
        if flight is not None and self.sync_timing:
            self._drain_megastep(flight)
            flight = None
        self._inflight = flight
        self._drain_admissions()
        self.pool.replenish()
        self._control_tick()

    def step(self) -> None:
        """One engine step. Megastep (``megastep_k`` > 0): one async
        double-buffered pipeline round (``_megastep_round``). Paged
        per-step: run the continuous-batching admission phase (open
        admissions on every free slot, advance them under the QoS chunk
        budget), dispatch one decode for every active slot (admitting slots
        ride along inactive, their writes masked), then drain admissions
        and the decode at the step's single drain point — a long prompt
        never stalls the decoders for more than the chunk budget. Dense:
        legacy synchronous admission, then decode. All tick the Pliant
        control loop at the step boundary."""
        self.step_count += 1
        self._process_capacity()   # deadline-reached capacity events cut
        self._expire_pending()     # over first, at the step boundary
        if self.paged and self.megastep_k > 0:
            self._megastep_round()
            return
        if self.paged:
            self._advance_admissions()
        else:
            self._admit()
        # the decode row set is FIXED here: slots activated at this step's
        # admission drain join the next step's decode
        rows = [i for i, req in enumerate(self.slots) if req is not None]
        if not rows:
            if self.paged:
                self._drain_admissions()  # no decode to overlap — drain now
                self.pool.replenish()     # keep headroom between steps
            self._control_tick()       # flush TTFT samples of 1-token admits
            return
        if self.paged:
            # map each live slot's write page before the step scatters to it
            # (live growth bypasses the reclaim limit — see serve.pages).
            # Grouped admission already reserved these pages, so this is a
            # no-op except for banded archs (which skip the reservation)
            dirty = False
            for i in rows:
                dirty |= self.pool.ensure_decode_page(
                    i, int(self.positions[i]))
            if dirty:
                self._push_blocks()
        t0 = time.perf_counter()
        with self._ctx():
            toks = jnp.asarray(self.cur_tokens)[:, None]
            pos = jnp.asarray(self.positions)
            if self.paged:
                act = jnp.asarray(
                    np.array([s is not None for s in self.slots]))
                args = (self.params, toks, pos, act, self.caches)
                cidx = 4
            else:
                args = (self.params, toks, pos, self.caches)
                cidx = 3
            out, new_caches = self._call_decode(
                self._decodes[self._active], args, cidx)
            self.caches = new_caches
            self.decode_dispatches += 1
            if self.paged:
                # the step's single drain point: admission chunks were
                # dispatched BEFORE the decode, so draining them here never
                # waits on the decode — their compute overlapped its
                # dispatch (satellite of the megastep pipeline)
                self._drain_admissions()
            # fused greedy: ``out`` is (B,) sampled token ids — B*4 bytes
            # off-device per step instead of the (B, V) logits matrix
            tb = time.perf_counter()
            out = np.asarray(out)
            self.drain_block_s += time.perf_counter() - tb
        dt = time.perf_counter() - t0
        self.step_latencies.append(dt)
        for entry in self._recovering:
            # recovery = event application -> first COMPLETED decode step on
            # the re-homed mesh (compile time of the cutover step included)
            entry["recovery_steps"] = \
                len(self.step_latencies) - entry["step_index"]
            entry["recovery_s"] = time.perf_counter() - entry.pop("_t_rehome")
        self._recovering.clear()
        now = time.perf_counter()
        if self._fused_sample:
            nxt_tokens = out[rows]
        else:
            nxt_tokens = self._sample_rows(
                out[rows], [self.slots[i] for i in rows])
        freed = False
        for i, nxt in zip(rows, nxt_tokens):
            req = self.slots[i]
            nxt = int(nxt)
            self.positions[i] += 1
            req.out.append(nxt)
            req.token_times.append(now)
            self.cur_tokens[i] = nxt
            self.row_dispatches += 1
            self.row_tokens += 1
            if len(req.out) >= req.max_new or (
                    self.eos_id >= 0 and nxt == self.eos_id):
                req.done = True
                self.slots[i] = None            # slot freed: continuous batch
                self._rngs.pop(req.uid, None)
                if self.paged:
                    freed |= self._free_slot(i)
            elif self._window_free:
                # banded arch: pages that fell out of every layer's window
                # are dead — return them so long decodes hold occupancy flat
                freed |= self.pool.release_window_pages(
                    i, int(self.positions[i]) - self._window_free)
        if freed:
            self._push_blocks()
        if self.paged:
            self.pool.replenish()      # watermark top-up, off the admission
        self._token_lat.extend([dt] * len(rows))   # path (between steps)
        self._control_tick()

    def _control_tick(self) -> None:
        """Monitor -> controller -> actuator at the step boundary. Variant
        swaps are deferred while an admission is in flight: a mid-prompt
        knob change would mix admission executables (and prefix tags)
        within one request."""
        if self.runtime is None:
            self._token_lat.clear()
            return
        if self._token_lat:
            self.runtime.monitor.record_many(self._token_lat)
            self._token_lat.clear()
        self.runtime.maybe_decide()
        if self._bound:
            # actuation arrived via the tenant adapter (request_variant);
            # apply any swap deferred by an in-flight admission
            self._apply_pending_variant()
        elif (self.runtime.active_variant != self._active
                and not self._admissions and not self._await_admit):
            # runtime owned by someone else (no tenant binding): follow its
            # decision state by polling, as before the tenant protocol
            self.set_variant(self.runtime.active_variant)

    @property
    def idle(self) -> bool:
        """Nothing to do: empty queue, no in-flight background admissions,
        no active slots. Drivers must check this (not just pending/slots)
        before parking — a paged admission spans multiple steps."""
        return (not self.pending and not self._admissions
                and not self._await_admit and self._inflight is None
                and all(s is None for s in self.slots))

    def run(self, max_steps: int = 0) -> None:
        """Step until idle. ``max_steps`` (0 = auto) is a runaway backstop,
        sized to the queued work: stall-free admission spends one step per
        prefill CHUNK, so the old flat cap silently truncated long-prompt
        workloads mid-flight. Hitting the cap non-idle raises — callers'
        stats must never summarize a silently truncated run."""
        if not max_steps:
            chunks = sum(-(-len(r.prompt) // max(self.prefill_chunk, 1)) + 2
                         for r in self.pending)
            decodes = sum(r.max_new for r in self.pending)
            max_steps = 10_000 + 2 * (chunks + decodes)
        steps = 0
        while not self.idle and steps < max_steps:
            self.step()
            steps += 1
        if not self.idle:
            raise RuntimeError(
                f"engine not idle after {steps} steps: "
                f"{len(self.pending)} pending, "
                f"{len(self._admissions)} admissions in flight, "
                f"{sum(s is not None for s in self.slots)} active slots")
