"""Batched prefill with cache fill: run the full-sequence forward ONCE and
hand the populated KV/SSM caches to incremental decode — the production
serving handoff (vs. feeding prompt tokens through decode steps one by one).

Additive module: reuses the per-kind mixers but emits cache entries as scan
outputs (stacked over layer groups, exactly the decode cache layout).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, LOCAL_ATTN, MAMBA, SHARED_ATTN,
                                ModelConfig)
from repro.approx.knobs import ApproxKnobs, PRECISE
from repro.models import attention as attn_mod
from repro.models import mamba2 as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models.attention import KVCache
from repro.models.common import apply_rope, rms_norm
from repro.models.lm import logits_fn
from repro.kernels import ref as kref
from repro.kernels import ops as kops


def _attn_block_with_kv(params, h, positions, cfg, kind, knobs, max_len):
    """Attention block that also returns the KVCache entry for decode."""
    hn = rms_norm(h, params["norm_attn"], cfg.norm_eps)
    B, S, _ = hn.shape
    hd = cfg.resolved_head_dim
    k = hn @ params["attn"]["wk"]
    v = hn @ params["attn"]["wv"]
    k = apply_rope(k.reshape(B, S, cfg.n_kv_heads, hd), positions,
                   cfg.rope_theta)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    mode = "window" if kind == LOCAL_ATTN else "causal"
    y = attn_mod.attention(params["attn"], hn, positions, cfg, mode=mode,
                           kv_keep_stride=knobs.kv_keep_stride)
    h = h + y
    hn2 = rms_norm(h, params["norm_mlp"], cfg.norm_eps)
    if "moe" in params:
        y2, _ = moe_mod.moe(params["moe"], hn2, cfg,
                            top_k=knobs.topk_override,
                            precision=knobs.matmul_precision)
    else:
        y2 = mlp_mod.mlp(params["mlp"], hn2,
                         precision=knobs.matmul_precision)
    h = h + y2
    # build the cache entry (ring layout, first S slots filled)
    W = min(cfg.window, max_len) if kind == LOCAL_ATTN else max_len
    kc = jnp.zeros((B, W, cfg.n_kv_heads, hd), k.dtype)
    vc = jnp.zeros_like(kc)
    pos = jnp.full((B, W), -1, jnp.int32)
    n_keep = min(S, W)
    kc = kc.at[:, :n_keep].set(k[:, S - n_keep:])
    vc = vc.at[:, :n_keep].set(v[:, S - n_keep:])
    pos = pos.at[:, :n_keep].set(
        jnp.broadcast_to(jnp.arange(S - n_keep, S), (B, n_keep)))
    cache = KVCache(kc, vc, pos, jnp.asarray(n_keep % W, jnp.int32)
                    if W > n_keep else jnp.asarray(0, jnp.int32))
    return h, cache


def _mamba_block_with_state(params, h, cfg, knobs):
    """Mamba block returning the MambaCache for decode handoff."""
    p = params["mixer"]
    hn = rms_norm(h, params["norm"], cfg.norm_eps)
    B, S, D = hn.shape
    di, nh, n = mamba_mod._dims(cfg)
    mm = kops.matmul(knobs.matmul_precision)
    z = mm(hn, p["in_z"])
    xs_in = mm(hn, p["in_x"])
    bc_in = hn @ p["in_bc"]
    xs, hist_x = mamba_mod._causal_conv(xs_in, p["conv_x"])
    bc, hist_bc = mamba_mod._causal_conv(bc_in, p["conv_bc"])
    dt_raw = hn @ p["in_dt"]
    b, c = jnp.split(bc, 2, axis=-1)
    xs4 = xs.reshape(B, S, nh, cfg.ssm.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, state = kref.ssd_chunked_ref(xs4, dt, a, b, c, chunk=cfg.ssm.chunk,
                                    d_skip=p["d_skip"], return_state=True)
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    h = h + mm(y, p["out_proj"])
    cache = mamba_mod.MambaCache(conv_x=hist_x, conv_bc=hist_bc, state=state)
    return h, cache


def prefill_chunk(params, tokens, start, caches, cfg: ModelConfig,
                  knobs: ApproxKnobs = PRECISE, *, mesh=None,
                  use_kernel: Optional[bool] = None, interpret: bool = False):
    """One prompt chunk against existing decode caches (chunked admission).

    tokens: (B, C); start: scalar int32 absolute position of the chunk's
    first token (traced — one executable serves every chunk of length C);
    caches: ``lm.init_caches`` layout. Returns (last-token logits (B,V) fp32,
    advanced caches). Iterating this over prompt chunks is the serving
    admission path: 32k prompts stream through fixed-size executables instead
    of one O(prompt) warmup per token or one giant full-sequence compile.

    Under a ``mesh`` each chunk's attention goes ring-sequence-parallel when
    ``dist.sharding.prefill_plan(cfg, mesh, C)`` applies (the same pure plan
    the engine and the explorer's pricing derive), else the loud unsharded
    fallback; ``use_kernel``/``interpret`` mirror the decode dispatch knobs.
    """
    from repro.models.blocks import block_prefill
    h = params["embed"][tokens]
    B, C, D = h.shape
    positions = start + jnp.broadcast_to(jnp.arange(C), (B, C))
    shared = params.get("shared")

    def group_body(h, xs):
        group_params, group_caches = xs
        new_caches = []
        for j, kind in enumerate(cfg.pattern):
            p = shared if kind == SHARED_ATTN else group_params.get(f"pos{j}")
            h, nc, _ = block_prefill(kind, p, h, positions, group_caches[j],
                                     cfg, knobs, mesh=mesh,
                                     use_kernel=use_kernel,
                                     interpret=interpret)
            new_caches.append(nc)
        return h, tuple(new_caches)

    h, new_caches = jax.lax.scan(group_body, h, (params["groups"], caches))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return logits_fn(params, h[:, -1], cfg), new_caches


def paged_prefill_chunk(params, tokens, start, caches, slot,
                        cfg: ModelConfig, knobs: ApproxKnobs = PRECISE,
                        dyn_scatter: bool = False, *, mesh=None,
                        use_kernel: Optional[bool] = None,
                        interpret: bool = False):
    """One prompt chunk for ONE slot of the paged engine caches.

    tokens: (1, C); start: traced scalar absolute position; slot: traced
    scalar batch row. Unlike ``prefill_chunk`` (which fills a fresh
    single-request cache that is then slot-scattered), this writes straight
    into the batched page pool through the slot's block table — there is no
    insert step, and prefix-shared pages are simply already mapped. Returns
    (last-token logits (1,V) fp32, advanced caches).
    """
    from repro.models.blocks import block_prefill_paged
    h = params["embed"][tokens]
    B, C, D = h.shape
    positions = start + jnp.broadcast_to(jnp.arange(C), (B, C))
    shared = params.get("shared")

    def group_body(h, xs):
        group_params, group_caches = xs
        new_caches = []
        for j, kind in enumerate(cfg.pattern):
            p = shared if kind == SHARED_ATTN else group_params.get(f"pos{j}")
            h, nc, _ = block_prefill_paged(kind, p, h, positions,
                                           group_caches[j], cfg, knobs,
                                           slot=slot, dyn_scatter=dyn_scatter,
                                           mesh=mesh, use_kernel=use_kernel,
                                           interpret=interpret)
            new_caches.append(nc)
        return h, tuple(new_caches)

    h, new_caches = jax.lax.scan(group_body, h, (params["groups"], caches))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return logits_fn(params, h[:, -1], cfg), new_caches


def prefill_with_cache(params, tokens, cfg: ModelConfig, max_len: int,
                       knobs: ApproxKnobs = PRECISE):
    """tokens: (B, S) -> (last-token logits (B,V) fp32, decode caches).

    The returned caches are exactly ``lm.init_caches`` layout with the first
    S positions populated; ``lm.decode_step`` continues from position S.
    """
    h = params["embed"][tokens]
    B, S, D = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    shared = params.get("shared")

    def group_body(h, group_params):
        caches = []
        for j, kind in enumerate(cfg.pattern):
            p = shared if kind == SHARED_ATTN else group_params.get(f"pos{j}")
            if kind == MAMBA:
                h, cache = _mamba_block_with_state(p, h, cfg, knobs)
            else:
                h, cache = _attn_block_with_kv(p, h, positions, cfg, kind,
                                               knobs, max_len)
            caches.append(cache)
        return h, tuple(caches)

    h, caches = jax.lax.scan(group_body, h, params["groups"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return logits_fn(params, h[:, -1], cfg), caches
