"""Process-wide tracing flags for loop-calibrated cost accounting.

XLA's HLO cost analysis counts a ``while`` body ONCE regardless of trip
count, so a scanned-layers program under-reports FLOPs/bytes/collective
traffic by ~n_layers. Full unrolling fixes the numbers but costs 10-30x in
compile time (unaffordable on this 1-core container).

Instead the dry-run compiles the scanned program (fast), then re-compiles one
*probe* per structural loop site with that site's ``unroll`` factor set to 2.
The probe-minus-base delta is exactly one extra copy of that loop's body, so

    true_cost = base + sum_i (trips_i - 1) * (probe_i - base)

with known static trip counts. Nested loops compose (see launch/dryrun.py).
``tests/test_dryrun.py`` validates the calibration against a fully-unrolled
compile on a small cell.

Loop sites: "groups" (layer-group scan, fwd/bwd/decode), "enc" (encoder
stack), "ce" (chunked cross-entropy), "micro" (gradient-accumulation scan).
("ssd" is retained for compatibility but unused: the SSD chunk-state
recurrence is a static python loop, so its bodies are counted exactly in the
base compile — a while loop there made the 2-point probe measure loop-shuttle
fusion noise instead of body cost.)
"""
UNROLL = {"groups": 1, "enc": 1, "ce": 1, "ssd": 1, "micro": 1}


def unroll(site: str) -> int:
    return UNROLL.get(site, 1)


def set_unroll(site: str, factor: int) -> None:
    UNROLL[site] = factor


def reset_unroll() -> None:
    for k in UNROLL:
        UNROLL[k] = 1
