"""ApproxKnobs: the TPU-native approximation design space (paper §3).

Each field is one knob; an *approximate variant* is a concrete knob setting.
All knobs are STATIC (they select a different compiled executable — the
DynamoRIO-analogue variant table in ``core/variants.py``):

* ``matmul_precision``  — lower-precision data types: bf16 -> int8 (W8A8).
* ``token_drop``        — loop perforation over the batch: train on a
                          statically smaller fraction of sequences per step.
* ``layer_skip``        — loop perforation over depth: keep a strided subset
                          of layer groups.
* ``kv_keep_stride``    — loop perforation over the attention KV loop
                          (off-diagonal KV-block perforation, prefill/train).
* ``topk_override``     — expert perforation for MoE archs (e.g. 8 -> 4).
* ``sync_period``       — synchronization elision: all-reduce gradients every
                          k steps (local-SGD style), k-1 steps elided.
* ``grad_compress``     — int8-compressed gradient reduction (elision's
                          bandwidth-saving sibling).
* ``kv_quant``          — serving-side: int8-quantized KV cache.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True, order=True)
class ApproxKnobs:
    matmul_precision: str = "bf16"   # "bf16" | "int8"
    token_drop: float = 0.0          # 0 .. <1: fraction of batch perforated
    layer_skip: float = 0.0          # 0 .. <1: fraction of layer groups skipped
    kv_keep_stride: int = 1          # 1 = precise; p>1 keeps 1/p old KV blocks
    topk_override: int = 0           # 0 = model default
    sync_period: int = 1             # 1 = precise sync every step
    grad_compress: str = "none"      # "none" | "int8"
    kv_quant: bool = False

    def is_precise(self) -> bool:
        return self == PRECISE

    def describe(self) -> str:
        parts = []
        if self.matmul_precision != "bf16":
            parts.append(self.matmul_precision)
        if self.token_drop:
            parts.append(f"drop{self.token_drop:.0%}")
        if self.layer_skip:
            parts.append(f"skip{self.layer_skip:.0%}")
        if self.kv_keep_stride > 1:
            parts.append(f"kvstride{self.kv_keep_stride}")
        if self.topk_override:
            parts.append(f"topk{self.topk_override}")
        if self.sync_period > 1:
            parts.append(f"sync/{self.sync_period}")
        if self.grad_compress != "none":
            parts.append(f"g{self.grad_compress}")
        if self.kv_quant:
            parts.append("kvq8")
        return "+".join(parts) or "precise"


PRECISE = ApproxKnobs()


def keep_groups(n_groups: int, layer_skip: float) -> tuple:
    """Static strided subset of layer groups for the layer-skip knob.

    Always keeps first and last group (embedding-adjacent layers matter most —
    mirrors the paper's observation that not all loop iterations contribute
    equally to quality)."""
    if layer_skip <= 0:
        return tuple(range(n_groups))
    n_keep = max(2, round(n_groups * (1.0 - layer_skip)))
    if n_keep >= n_groups:
        return tuple(range(n_groups))
    import numpy as np
    idx = np.linspace(0, n_groups - 1, n_keep).round().astype(int)
    return tuple(sorted(set(int(i) for i in idx)))
