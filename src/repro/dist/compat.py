"""Shims for jax API drift.

The codebase (and ``tests/test_dist.py``) is written against the current jax
surface — ``jax.set_mesh`` as a context manager, ``jax.shard_map`` with
``axis_names``/``check_vma`` keywords — but the pinned CPU environment runs
jax 0.4.37, where those live under older names:

* ``jax.shard_map``  -> ``jax.experimental.shard_map.shard_map`` with
  ``check_rep`` instead of ``check_vma`` and no ``axis_names`` keyword (the
  legacy call is fully manual over every mesh axis, which subsumes the
  ``axis_names`` subsets used here since unnamed axes only ever carry
  replicated values under ``check_vma=False``).
* ``jax.set_mesh``   -> entering the legacy ``Mesh`` context manager.

``install()`` backfills the modern names onto the ``jax`` namespace; importing
``repro.dist`` (or ``repro.launch.mesh``) triggers it, so any entrypoint that
builds a mesh can rely on the modern API.
"""
from __future__ import annotations

import jax


def _legacy_shard_map(f=None, *, mesh=None, in_specs=None, out_specs=None,
                      axis_names=None, check_vma=None, check_rep=None):
    """Modern ``jax.shard_map`` signature lowered to the 0.4.x API."""
    from jax.experimental.shard_map import shard_map as _sm
    del axis_names  # fully-manual over every mesh axis (see module docstring)
    if check_rep is None:
        check_rep = True if check_vma is None else bool(check_vma)
    if f is None:                       # used as a decorator factory
        return lambda fn: _legacy_shard_map(fn, mesh=mesh, in_specs=in_specs,
                                            out_specs=out_specs,
                                            check_rep=check_rep)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_rep)


def _legacy_set_mesh(mesh):
    """On 0.4.x a concrete ``Mesh`` is itself the context manager."""
    return mesh


def shard_map(*args, **kwargs):
    """Dispatch to the native ``jax.shard_map`` when present, else the shim."""
    native = getattr(jax, "shard_map", None)
    if native is not None and native is not _legacy_shard_map:
        return native(*args, **kwargs)
    return _legacy_shard_map(*args, **kwargs)


def set_mesh(mesh):
    native = getattr(jax, "set_mesh", None)
    if native is not None and native is not _legacy_set_mesh:
        return native(mesh)
    return _legacy_set_mesh(mesh)


def active_mesh():
    """The mesh of the enclosing ``set_mesh`` context, or None.

    Annotation helpers use this to become no-ops when tracing single-device
    programs (the reference paths in tests).
    """
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:  # pragma: no cover - future jax moves the internals
        pass
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:  # pragma: no cover - modern jax path
        m = get_abstract()
        if m is not None and getattr(m, "axis_names", ()):
            return m
    return None


def install() -> None:
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _legacy_shard_map
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = _legacy_set_mesh
