"""Deflation-grade elasticity: capacity events, deterministic fault
injection, and live (mid-flight) elastic reshard.

The harshest resource pressure in a real fleet is not a slow co-tenant but
*capacity revocation*: preempted devices, transient servers reclaimed with a
deadline, a co-tenant's emergency quota grab, a flaky interconnect failing a
collective. The VM-deflation literature (PAPERS.md) shows interactive
services can ride these out gracefully instead of being killed; this module
is the substrate that lets every driver in the repo script and survive them:

* ``CapacityEvent`` — one revocation/restore/quota/collective incident, with
  an optional grace ``deadline_steps`` (transient-server notice: the victim
  keeps the capacity for that many steps and must be off it by the end).
* ``FaultInjector`` — a deterministic, seedable event schedule keyed by the
  driver's step counter. Drivers poll ``due(step)`` each iteration and route
  the events to their engine/runtime/tenants; the same script replayed under
  the same seed produces the same faults, so chaos runs are reproducible and
  CI can assert token parity against an unfaulted reference.
* ``surviving_mesh`` — the largest rectangular mesh over the devices that
  remain after a revocation, preserving model-parallel axis sizes (weight
  dims divide them) and shrinking batch axes. Layout feasibility downstream
  (slot-affinity decode plan, ring-prefill plan) is re-derived by the same
  pure plan functions the engine always uses — a shrink that loses the fast
  path degrades loudly to the gather/unsharded fallback, it never corrupts.
* ``reshard_live`` — the checkpoint-time elastic reshard (``ckpt.restore``
  onto any mesh) without the disk round-trip: host-stage the tree, then
  ``device_put`` with the target shardings. Used for mid-flight params AND
  optimizer state when a train job shrinks, and for serve caches when an
  engine re-homes its pool.

Kinds:

* ``REVOKE``   — ``count`` devices (or an explicit ``devices`` tuple) leave
  at ``step + deadline_steps``; the grace window is the degradation window.
* ``RESTORE``  — revoked devices return (all of them when ``devices`` is
  empty); the tenant re-inflates through the same Fig. 3 slack path it
  de-approximated through.
* ``QUOTA_CUT`` / ``QUOTA_RESTORE`` — a co-tenant's emergency grab of
  ``quanta`` pool quanta: enforced as a hard capacity floor on the page
  pool, *separate* from the Pliant reclaim ledger so the arbiter's
  accounting never diverges from its own actuations.
* ``COLLECTIVE_FAILURE`` — ``count`` transient collective failures: the
  engine discards the failed step's (uncommitted, functional) results and
  re-issues it, counting the retry.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

REVOKE = "revoke"
RESTORE = "restore"
QUOTA_CUT = "quota_cut"
QUOTA_RESTORE = "quota_restore"
COLLECTIVE_FAILURE = "collective_failure"

KINDS = (REVOKE, RESTORE, QUOTA_CUT, QUOTA_RESTORE, COLLECTIVE_FAILURE)

# kinds that take capacity OUT (pressure on) vs give it BACK (pressure off)
PRESSURE_ON = (REVOKE, QUOTA_CUT)
PRESSURE_OFF = (RESTORE, QUOTA_RESTORE)


@dataclass(frozen=True)
class CapacityEvent:
    """One scripted capacity incident, keyed by the driver's step counter."""
    kind: str
    step: int                          # driver step at which the notice lands
    count: int = 0                     # devices to revoke / failures to inject
    devices: Tuple[int, ...] = ()      # explicit device ids (overrides count)
    quanta: int = 0                    # pool-quanta size of a quota cut
    deadline_steps: int = 0            # grace: revocation effective at
                                       # step + deadline_steps (0 = immediate)

    def __post_init__(self):
        assert self.kind in KINDS, self.kind
        assert self.step >= 0 and self.deadline_steps >= 0, self


class FaultInjector:
    """Deterministic, seedable capacity-event schedule.

    Drivers poll ``due(step)`` once per loop iteration; every event whose
    ``step`` has arrived is handed back exactly once, in (step, schedule
    order). ``parse`` builds a schedule from the compact CLI grammar used by
    ``launch/serve.py`` and ``launch/train.py``::

        revoke@20:2        revoke 2 devices at step 20 (immediate)
        revoke@20+5:2      same, with a 5-step grace deadline
        restore@60         restore every revoked device at step 60
        quota_cut@10:3     cut 3 pool quanta at step 10
        quota_restore@40   lift the quota cut
        fail@15:2          2 transient collective failures from step 15

    ``random_script`` derives a reproducible paired revoke/restore schedule
    from a seed — the chaos-smoke generator.
    """

    def __init__(self, events: Sequence[CapacityEvent] = ()):
        self._events: List[CapacityEvent] = []
        self._seq: List[int] = []      # schedule order (stable tie-break)
        self.delivered: List[CapacityEvent] = []
        for ev in events:
            self.schedule(ev)

    def schedule(self, ev: CapacityEvent) -> None:
        self._events.append(ev)
        self._seq.append(len(self._seq))

    def pending(self) -> int:
        return len(self._events)

    def due(self, step: int) -> List[CapacityEvent]:
        """Pop (in schedule-stable step order) every event now due."""
        take = sorted((i for i, ev in enumerate(self._events)
                       if ev.step <= step),
                      key=lambda i: (self._events[i].step, self._seq[i]))
        out = [self._events[i] for i in take]
        for i in sorted(take, reverse=True):
            del self._events[i]
            del self._seq[i]
        self.delivered.extend(out)
        return out

    _ALIASES = {"fail": COLLECTIVE_FAILURE, COLLECTIVE_FAILURE:
                COLLECTIVE_FAILURE, **{k: k for k in KINDS}}

    @classmethod
    def parse(cls, script: str) -> "FaultInjector":
        events = []
        for part in filter(None, (p.strip() for p in script.split(","))):
            head, _, arg = part.partition(":")
            kind, _, when = head.partition("@")
            assert kind in cls._ALIASES, f"unknown event kind {kind!r}"
            kind = cls._ALIASES[kind]
            step, _, grace = when.partition("+")
            k = int(arg) if arg else 0
            events.append(CapacityEvent(
                kind, int(step),
                count=k if kind in (REVOKE, COLLECTIVE_FAILURE) else 0,
                quanta=k if kind == QUOTA_CUT else 0,
                deadline_steps=int(grace) if grace else 0))
        return cls(events)

    @classmethod
    def random_script(cls, *, n_rounds: int, max_step: int, n_devices: int,
                      seed: int = 0, deadline_steps: int = 2
                      ) -> "FaultInjector":
        """Seed-deterministic paired revoke/restore rounds: each round
        revokes 1..n_devices//2 devices at a random step and restores them
        at a later one. Same seed, same script — chaos is replayable."""
        rng = np.random.default_rng(seed)
        events = []
        slots = sorted(rng.choice(max(max_step, 2 * n_rounds),
                                  size=2 * n_rounds, replace=False))
        for r in range(n_rounds):
            k = int(rng.integers(1, max(n_devices // 2, 1) + 1))
            events.append(CapacityEvent(REVOKE, int(slots[2 * r]), count=k,
                                        deadline_steps=deadline_steps))
            events.append(CapacityEvent(RESTORE, int(slots[2 * r + 1])))
        return cls(events)


# ------------------------------------------------------------ mesh shrink --

# axes that carry batch/sequence work and may shrink under revocation; every
# other axis (``model`` above all) is pinned — weight dims divide it, so
# shrinking it would invalidate every parameter sharding
BATCH_AXES = ("pod", "data")


def pick_revoked(mesh, count: int, already=()) -> Tuple[int, ...]:
    """Deterministic device choice for a ``count``-only revocation: the
    highest-ordinal devices of the mesh not already revoked — the tail of
    the batch-axis split, so survivors stay a contiguous prefix (the same
    contiguous split GSPMD and the slot-affinity pool use)."""
    ids = sorted(int(d.id) for d in np.asarray(mesh.devices).ravel()
                 if int(d.id) not in set(already))
    return tuple(ids[len(ids) - count:]) if count else ()


def surviving_mesh(mesh, revoked, *, prefer_divisor_of: int = 0):
    """(new_mesh, reason) — the largest rectangular mesh over the surviving
    devices.

    Model-parallel axes keep their size (weights are sharded over them);
    batch axes shrink, outermost first. When ``prefer_divisor_of`` is set
    (the engine passes ``batch_slots``), a smaller batch-axis size that
    divides it is preferred over a larger one that does not — keeping the
    slot-affinity fast path alive beats keeping spare devices busy on the
    gather fallback. Returns ``(None, reason)`` when not even the pinned
    axes fit the survivors (callers fall back to single-device / replicated
    execution)."""
    import jax

    if mesh is None:
        return None, "no mesh to shrink"
    revoked = {int(r) for r in revoked}
    survivors = [d for d in sorted(np.asarray(mesh.devices).ravel(),
                                   key=lambda d: int(d.id))
                 if int(d.id) not in revoked]
    if not revoked:
        return mesh, "nothing revoked"
    axes = list(mesh.axis_names)
    sizes = {a: int(mesh.shape[a]) for a in axes}
    pinned = int(np.prod([sizes[a] for a in axes if a not in BATCH_AXES]))
    if pinned > len(survivors):
        return None, (f"{len(survivors)} survivors cannot carry the pinned "
                      f"model axes (need {pinned})")
    batch = [a for a in axes if a in BATCH_AXES]
    new_sizes = dict(sizes)
    budget = len(survivors) // pinned      # total batch-axis capacity left
    # shrink outermost batch axis first; inner ones only if still over budget
    for ai, a in enumerate(batch):
        inner = int(np.prod([new_sizes[b] for b in batch[ai + 1:]] or [1]))
        cap = max(budget // inner, 1)
        n = min(sizes[a], cap)
        if prefer_divisor_of:
            div = max((d for d in range(1, n + 1)
                       if prefer_divisor_of % d == 0), default=1)
            # a dividing size keeps the slot-affinity plan; only fall back
            # to the non-dividing maximum when dividing costs > half of it
            n = div if div * 2 >= n else n
        new_sizes[a] = n
        budget //= n * max(inner // int(np.prod(
            [sizes[b] for b in batch[ai + 1:]] or [1])), 1) or 1
        budget = (len(survivors) // pinned) // int(np.prod(
            [new_sizes[b] for b in batch[: ai + 1]]))
    need = pinned * int(np.prod([new_sizes[a] for a in batch] or [1]))
    assert need <= len(survivors), (new_sizes, len(survivors))
    shape = tuple(new_sizes[a] for a in axes)
    dev = np.asarray(survivors[:need]).reshape(shape)
    reason = (f"{need} of {len(survivors)} survivors as "
              + "x".join(str(s) for s in shape))
    return jax.sharding.Mesh(dev, tuple(axes)), reason


# ----------------------------------------------------------- live reshard --

def host_stage(tree):
    """Pull a (possibly sharded) pytree to host numpy — the first half of
    every elastic move: once staged, the source devices may disappear."""
    import jax
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)


def reshard_live(tree, shardings=None):
    """Mid-flight elastic reshard: the checkpoint-restore path without the
    disk round-trip. Host-stages ``tree`` and re-``device_put``s it with
    ``shardings`` (None = default placement on the current backend). Works
    across arbitrary source/target meshes because the staged copy is
    unsharded-logical, exactly like ``ckpt.restore``."""
    import jax
    staged = host_stage(tree)
    if shardings is None:
        return jax.tree.map(jax.device_put, staged)
    return jax.device_put(staged, shardings)
