"""First-class distribution layer.

* ``compat``      — shims for jax API drift (``jax.set_mesh``/``jax.shard_map``
                    on the pinned 0.4.x CPU jax). Imported for its side effect:
                    importing ``repro.dist`` installs the shims.
* ``sharding``    — logical-axis -> mesh-axis policies: ``param_shardings``,
                    ``cache_shardings``, ``input_shardings``, ``batch_pspec``.
* ``annotate``    — activation-sharding constraints (``constrain_batch``,
                    ``constrain_vocab``) driven by launcher-set batch axes.
* ``collectives`` — wire-compressed collectives: ``compressed_pmean`` (the
                    ``grad_compress`` knob) and ``pod_sync_params`` (the
                    ``sync_period`` knob's periodic pod-level sync).
"""
from repro.dist import compat as _compat

_compat.install()
