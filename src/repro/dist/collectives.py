"""Wire-compressed collectives — the executable side of the Pliant sync knobs.

* ``compressed_pmean``  — mean over a shard_map axis with an int8-quantized
  wire format: each peer ships (int8 payload, one f32 scale) instead of f32,
  ~4x fewer collective bytes. This is the real implementation of the
  ``grad_compress`` knob.
* ``pod_sync_params``   — periodic pod-level parameter sync for the
  ``sync_period`` knob (local-SGD style): a train step under
  ``sync_period=k`` carries no cross-pod collectives; the launcher calls this
  every k steps instead (``launch/train.py``), amortizing the wire cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import compat


def _quantize_int8(x):
    """Symmetric per-tensor int8: (payload int8, scale f32 scalar)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_pmean(tree, axis_name: str):
    """Mean over ``axis_name`` (inside shard_map) with int8 wire payloads.

    Scales differ per peer, so the reduction is an all_gather of the int8
    payloads + scales followed by a local dequantized mean — the wire carries
    int8; only the (scalar-per-peer) scales travel as f32.
    """
    def one(x):
        q, scale = _quantize_int8(x)
        qg = jax.lax.all_gather(q, axis_name)            # int8 on the wire
        sg = jax.lax.all_gather(scale, axis_name)
        deq = qg.astype(jnp.float32) * sg.reshape((-1,) + (1,) * x.ndim)
        return jnp.mean(deq, axis=0).astype(x.dtype)
    return jax.tree.map(one, tree)


def _pspec_of(s):
    return s.spec if isinstance(s, NamedSharding) else s


def pod_sync_params(params, mesh, *, compress: bool = False, pspecs=None,
                    axis: str = "pod"):
    """Average ``params`` across the ``axis`` mesh dimension.

    Jit-able from OUTSIDE shard_map: wraps the reduction in a (fully manual)
    shard_map whose in/out specs come from ``pspecs`` (NamedSharding or
    PartitionSpec tree; default replicated). With per-pod-identical params the
    uncompressed sync is exact; ``compress=True`` routes the payload through
    the int8 wire format (used by the dry-run to price the sync step).
    """
    if mesh is None or axis not in mesh.shape:
        return params
    if pspecs is None:
        specs = jax.tree.map(lambda _: P(), params)
    else:
        specs = jax.tree.map(_pspec_of, pspecs,
                             is_leaf=lambda s: isinstance(s, (NamedSharding,
                                                              P)))

    def body(p):
        if compress:
            return compressed_pmean(p, axis)
        return jax.tree.map(lambda x: jax.lax.pmean(x, axis), p)

    return compat.shard_map(body, mesh=mesh, in_specs=(specs,),
                            out_specs=specs, check_vma=False)(params)
