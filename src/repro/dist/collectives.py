"""Wire-compressed collectives — the executable side of the Pliant sync knobs.

* ``compressed_pmean``  — mean over a shard_map axis with an int8-quantized
  wire format: each peer ships (int8 payload, one f32 scale) instead of f32,
  ~4x fewer collective bytes. This is the real implementation of the
  ``grad_compress`` knob.
* ``grad_sync``         — the per-step gradient reduction as ONE owned
  shard_map region: explicit in-pod pmean over ``data`` plus (when the knobs
  call for it) the cross-pod wire in the same region. Because the pod wire is
  either traced into the region or not, ``sync_period`` elision is a
  trace-time fact — the compiled step carries zero pod collective bytes.
* ``pod_sync_params``   — periodic pod-level parameter sync for the
  ``sync_period`` knob (local-SGD style): a train step under
  ``sync_period=k`` carries no cross-pod collectives; the launcher calls this
  every k steps instead (``launch/train.py``), amortizing the wire cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import compat


def _quantize_int8(x):
    """Symmetric per-tensor int8: (payload int8, scale f32 scalar)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_pmean(tree, axis_name: str):
    """Mean over ``axis_name`` (inside shard_map) with int8 wire payloads.

    Scales differ per peer, so the reduction is an all_gather of the int8
    payloads + scales followed by a local dequantized mean — the wire carries
    int8; only the (scalar-per-peer) scales travel as f32.
    """
    def one(x):
        q, scale = _quantize_int8(x)
        qg = jax.lax.all_gather(q, axis_name)            # int8 on the wire
        sg = jax.lax.all_gather(scale, axis_name)
        deq = qg.astype(jnp.float32) * sg.reshape((-1,) + (1,) * x.ndim)
        return jnp.mean(deq, axis=0).astype(x.dtype)
    return jax.tree.map(one, tree)


def _pspec_of(s):
    return s.spec if isinstance(s, NamedSharding) else s


def _spec_axes(spec):
    """Mesh-axis names a PartitionSpec partitions over (flattened)."""
    names = set()
    for part in spec:
        if part is None:
            continue
        if isinstance(part, (tuple, list)):
            names.update(part)
        else:
            names.add(part)
    return names


def _is_spec(s):
    return isinstance(s, (NamedSharding, P))


def grad_sync(grads, mesh, *, pod_wire: bool = True, compress: bool = False,
              pspecs=None, data_axis: str = "data", pod_axis: str = "pod"):
    """The whole per-step gradient reduction as one shard_map region.

    In-pod: an explicit pmean over ``data_axis`` for every leaf that is not
    itself ``data``-sharded (FSDP leaves already live reduced-and-scattered).
    On grads that GSPMD has already reduced this is numerically the identity,
    but it makes the in-pod collective *owned* — visible in the traced jaxpr,
    priceable by the dry-run, and a seam the knobs can rewrite.

    Cross-pod: when ``pod_wire`` (``sync_period == 1``) the pod mean rides in
    the SAME region, int8-compressed when ``compress``. When False the pod
    collective is never traced: sync elision drops the wire bytes from the
    executable itself, not just from the accounting.
    """
    if mesh is None:
        return grads
    have_data = data_axis in mesh.shape
    have_pod = pod_wire and pod_axis in mesh.shape
    if not (have_data or have_pod):
        return grads
    if pspecs is None:
        specs = jax.tree.map(lambda _: P(), grads)
    else:
        specs = jax.tree.map(_pspec_of, pspecs, is_leaf=_is_spec)
    axis_sets = [_spec_axes(s)
                 for s in jax.tree.leaves(specs, is_leaf=_is_spec)]

    def body(g):
        gl, tdef = jax.tree.flatten(g)
        if have_data:
            gl = [x if data_axis in names else jax.lax.pmean(x, data_axis)
                  for x, names in zip(gl, axis_sets)]
        g = tdef.unflatten(gl)
        if have_pod:
            if compress:
                g = compressed_pmean(g, pod_axis)
            else:
                g = jax.tree.map(lambda x: jax.lax.pmean(x, pod_axis), g)
        return g

    return compat.shard_map(body, mesh=mesh, in_specs=(specs,),
                            out_specs=specs, check_vma=False)(grads)


def pod_sync_params(params, mesh, *, compress: bool = False, pspecs=None,
                    axis: str = "pod"):
    """Average ``params`` across the ``axis`` mesh dimension.

    Jit-able from OUTSIDE shard_map: wraps the reduction in a (fully manual)
    shard_map whose in/out specs come from ``pspecs`` (NamedSharding or
    PartitionSpec tree; default replicated). With per-pod-identical params the
    uncompressed sync is exact; ``compress=True`` routes the payload through
    the int8 wire format (used by the dry-run to price the sync step).
    """
    if mesh is None or axis not in mesh.shape:
        return params
    if pspecs is None:
        specs = jax.tree.map(lambda _: P(), params)
    else:
        specs = jax.tree.map(_pspec_of, pspecs,
                             is_leaf=lambda s: isinstance(s, (NamedSharding,
                                                              P)))

    def body(p):
        if compress:
            return compressed_pmean(p, axis)
        return jax.tree.map(lambda x: jax.lax.pmean(x, axis), p)

    return compat.shard_map(body, mesh=mesh, in_specs=(specs,),
                            out_specs=specs, check_vma=False)(params)
