"""Logical-axis -> mesh-axis sharding policies.

Every module in ``models/`` declares its parameters as ``ParamSpec`` trees
with *logical* axis names (``embed``, ``mlp``, ``q_heads``, ``expert``, ...).
This module maps those names onto mesh axes under a named policy and returns
``NamedSharding`` trees with the exact same pytree structure as the params —
so ``jax.device_put(params, param_shardings(...))`` and
``jax.jit(..., in_shardings=...)`` work directly.

Policies:

* ``"replicated"`` — everything everywhere (CPU smoke fallback).
* ``"tp"``         — megatron-style tensor parallelism over ``model``:
                     hidden/expert/vocab dims sharded, embed dim replicated.
* ``"fsdp_tp"``    — ``tp`` plus the embed dim FSDP-sharded over ``data``.

A dim is only sharded when its size divides the mesh axis; each mesh axis is
used at most once per array (first matching dim wins), so e.g. MoE expert
weights shard experts over ``model`` and leave ``mlp`` replicated rather than
double-booking the axis.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.common import ParamSpec, spec_tree_map

# logical axis name -> mesh axis, per policy. Axes not listed stay replicated.
_TP_RULES = {
    "vocab": "model",
    "q_heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "expert": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
}

POLICIES = {
    "replicated": {},
    "tp": dict(_TP_RULES),
    "fsdp_tp": dict(_TP_RULES, embed="data"),
}


def default_policy(cfg: ModelConfig) -> str:
    """Weights at production scale never fit replicated: FSDP+TP everywhere."""
    return "fsdp_tp"


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    flat = axes if isinstance(axes, tuple) else (axes,)
    n = 1
    for a in flat:
        n *= mesh.shape[a]
    return n


def _axes_present(mesh, axes) -> bool:
    flat = axes if isinstance(axes, tuple) else (axes,)
    return all(a in mesh.shape for a in flat)


def _spec_for(spec: ParamSpec, rules, mesh) -> P:
    used = set()
    out = []
    for size, name in zip(spec.shape, spec.axes):
        ax = rules.get(name)
        if (ax is None or ax not in mesh.shape or ax in used
                or size % mesh.shape[ax] != 0):
            out.append(None)
        else:
            out.append(ax)
            used.add(ax)
    return P(*out)


def param_shardings(cfg: ModelConfig, mesh, mode: Optional[str] = None):
    """NamedSharding tree matching the param tree of ``cfg``'s family."""
    from repro.models import api
    rules = POLICIES[mode or default_policy(cfg)]
    return spec_tree_map(
        lambda s: NamedSharding(mesh, _spec_for(s, rules, mesh)),
        api.model_specs(cfg))


# ----------------------------------------------------------------- inputs --

def batch_pspec(global_batch: int, mesh) -> P:
    """PartitionSpec for the batch dim: greedily shard over (pod, data)."""
    use, n = [], 1
    for a in ("pod", "data"):
        if a in mesh.shape and global_batch % (n * mesh.shape[a]) == 0:
            use.append(a)
            n *= mesh.shape[a]
    if not use:
        return P()
    return P(tuple(use) if len(use) > 1 else use[0])


def input_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Shardings matching ``api.input_specs(cfg, shape)`` key-for-key."""
    from repro.models import api
    bspec = batch_pspec(shape.global_batch, mesh)
    b = bspec[0] if len(bspec) else None
    return {
        name: NamedSharding(mesh, P(b, *([None] * (len(s.shape) - 1))))
        for name, s in api.input_specs(cfg, shape).items()
    }


# ------------------------------------------------------- paged decode plan --

class PagedDecodePlan:
    """Slot-affinity layout of the sharded fused paged decode: the batch
    mesh axes the slot/page dims split over, the resulting shard count, and
    the mesh axis (if any) the kv_heads dim additionally splits over.

    The plan is a pure function of (cfg, mesh, batch_slots, n_pages), so the
    engine (pool sizing), ``cache_shardings`` (device placement), and the
    traced decode step (shard_map specs + block-table rebasing) all derive
    the SAME layout independently — no side channel between host allocator
    and compiled executable."""

    def __init__(self, batch_axes, n_shards: int, kv_head_axis):
        self.batch_axes = batch_axes      # mesh axis name or tuple of names
        self.n_shards = n_shards
        self.kv_head_axis = kv_head_axis  # "model" or None (replicated)

    def __repr__(self):
        return (f"PagedDecodePlan(batch_axes={self.batch_axes!r}, "
                f"n_shards={self.n_shards}, "
                f"kv_head_axis={self.kv_head_axis!r})")


def paged_decode_plan(cfg: ModelConfig, mesh, batch_slots: int,
                      n_pages: int = 0):
    """(plan, reason) for sharding the fused paged-attention decode kernel.

    Returns ``(PagedDecodePlan, "")`` when the pool can be split with slot
    affinity — slots and physical pages partitioned over the same batch
    axes, so each device's kernel invocation resolves its block tables
    entirely against local pages — else ``(None, reason)`` and the caller
    falls back to the GSPMD gather path. ``n_pages`` <= 0 skips the page-dim
    divisibility check (pool sizing rounds it up to fit afterwards)."""
    if mesh is None:
        return None, "no mesh (single device)"
    bspec = batch_pspec(batch_slots, mesh)
    if not len(bspec):
        return None, (f"batch_slots={batch_slots} does not divide any batch "
                      "mesh axis — slots cannot split with affinity")
    b = bspec[0]
    n = _axis_size(mesh, b)
    if n_pages > 0 and n_pages % n != 0:
        return None, (f"n_pages={n_pages} does not split over batch axes "
                      f"{b!r} (size {n})")
    g_ax = ("model" if ("model" in mesh.shape
                        and cfg.n_kv_heads % mesh.shape["model"] == 0)
            else None)
    return PagedDecodePlan(b, n, g_ax), ""


# ------------------------------------------------------- ring prefill plan --

class PrefillPlan:
    """Sequence layout of the ring-attention chunked-prefill cell: the single
    mesh axis the chunk's query dim (and the rotating K/V context) splits
    over, the resulting shard count, and the mesh axis (if any) the kv_heads
    dim additionally splits over.

    Like ``PagedDecodePlan``, the plan is a pure function of
    ``(cfg, mesh, chunk_len)`` so the prefill cell (shard_map specs + ring
    schedule), the admission-step builders, and the explorer's compile-time
    pricing all derive the SAME sequence layout independently — no side
    channel between them. Causal chunks are laid out *striped* (round-robin
    query rows per shard) for ring load balance; window chunks stay
    contiguous so whole hops outside the band can be skipped — that choice
    is per attention call, not part of the plan."""

    def __init__(self, seq_axis: str, n_shards: int, kv_head_axis):
        self.seq_axis = seq_axis          # single mesh axis name
        self.n_shards = n_shards
        self.kv_head_axis = kv_head_axis  # "model" or None (replicated)

    def __repr__(self):
        return (f"PrefillPlan(seq_axis={self.seq_axis!r}, "
                f"n_shards={self.n_shards}, "
                f"kv_head_axis={self.kv_head_axis!r})")


def prefill_plan(cfg: ModelConfig, mesh, chunk_len: int):
    """(plan, reason) for sequence-sharding one admission chunk's attention.

    Returns ``(PrefillPlan, "")`` when a batch-side mesh axis can carry the
    ring — a single axis from ("pod", "data") with size > 1 that does not
    exceed the chunk length (each shard needs at least one resident query
    row) — else ``(None, reason)`` and the caller takes the loud GSPMD
    unsharded path. A single axis keeps the ``ppermute`` ring schedule
    trivial; the largest eligible axis wins. kv_heads additionally split
    over ``model`` when divisible, mirroring the decode plan."""
    if mesh is None:
        return None, "no mesh (single device)"
    cand = [a for a in ("pod", "data") if mesh.shape.get(a, 1) > 1]
    if not cand:
        return None, ("no batch mesh axis (pod/data) with size > 1 to carry "
                      "the sequence ring")
    cand = [a for a in cand if mesh.shape[a] <= chunk_len]
    if not cand:
        return None, (f"chunk_len={chunk_len} shorter than every batch mesh "
                      "axis — no resident query row per shard")
    ax = max(cand, key=lambda a: mesh.shape[a])
    g_ax = ("model" if ("model" in mesh.shape
                        and cfg.n_kv_heads % mesh.shape["model"] == 0)
            else None)
    return PrefillPlan(ax, mesh.shape[ax], g_ax), ""


# ----------------------------------------------------------------- caches --

def cache_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                    seq_axis: str = "model", quantized: bool = False,
                    paged=None):
    """(sharding tree, abstract caches) for sequence-sharded decode.

    Dense KV caches shard the cache-length dim over ``seq_axis`` (GSPMD
    lowers the attention softmax over it to partial reductions) and the
    batch dim over the batch axes. Paged pools (``paged`` = PageSpec) come
    in two layouts: a slot-affinity spec (``n_shards`` > 1) shards the
    physical-page dim over the BATCH axes — the same contiguous split the
    block table's slot dim gets, so a slot's pages are device-local and the
    fused kernel runs per-shard under shard_map — with the kv_heads dim
    optionally split over ``model``; a legacy spec shards the page dim over
    ``seq_axis`` (the gather and the one-hot scatter are both elementwise
    over it). Mamba states have no sequence dim; they shard batch only.
    Returns trees with the exact structure of ``init_caches`` /
    ``init_paged_caches``.
    """
    from repro.models import api
    from repro.models.attention import KVCache, PagedKVCache
    from repro.models.mamba2 import MambaCache
    caches_abs = api.abstract_caches(cfg, shape.global_batch, shape.seq_len,
                                     quantized=quantized, paged=paged)
    bspec = batch_pspec(shape.global_batch, mesh)
    b = bspec[0] if len(bspec) else None

    def batch_ax(n):
        return b if (b is not None and n % _axis_size(mesh, b) == 0) else None

    def seq_ax(n):
        ok = (seq_axis in mesh.shape and n % mesh.shape[seq_axis] == 0)
        return seq_axis if ok else None

    def one(c):
        # leaves are group-stacked: dim 0 = layer groups (scan carried)
        if isinstance(c, PagedKVCache):
            if getattr(paged, "n_shards", 1) > 1:
                # slot-affinity layout: pages split over the batch axes like
                # the slots themselves; kv_heads over model when divisible
                pg = batch_ax(c.kp.shape[1])
                g_ax = ("model" if ("model" in mesh.shape and
                                    c.kp.shape[3] % mesh.shape["model"] == 0)
                        else None)
                kv = NamedSharding(mesh, P(None, pg, None, g_ax, None))
                return PagedKVCache(
                    kp=kv, vp=kv,
                    ppos=NamedSharding(mesh, P(None, pg, None)),
                    block=NamedSharding(
                        mesh, P(None, batch_ax(c.block.shape[1]), None)))
            pg = seq_ax(c.kp.shape[1])
            kv = NamedSharding(mesh, P(None, pg, None, None, None))
            return PagedKVCache(
                kp=kv, vp=kv,
                ppos=NamedSharding(mesh, P(None, pg, None)),
                block=NamedSharding(mesh,
                                    P(None, batch_ax(c.block.shape[1]), None)))
        if isinstance(c, KVCache):
            bb, ss = batch_ax(c.k.shape[1]), seq_ax(c.k.shape[2])
            kv = NamedSharding(mesh, P(None, bb, ss, None, None))
            return KVCache(
                k=kv, v=kv,
                pos=NamedSharding(mesh, P(None, bb, ss)),
                cursor=NamedSharding(mesh, P(None)))
        assert isinstance(c, MambaCache), type(c)
        return jax.tree.map(
            lambda x: NamedSharding(
                mesh, P(None, batch_ax(x.shape[1]), *([None] * (x.ndim - 2)))),
            c)

    sh = jax.tree.map(one, caches_abs,
                      is_leaf=lambda x: isinstance(
                          x, (KVCache, PagedKVCache, MambaCache)))
    return sh, caches_abs


def megastep_shardings(param_sh, cache_sh):
    """jit sharding specs for the fused K-token megastep executable.

    Signature (``train.step.make_paged_megastep``): ``step(params, cur,
    pos, alive, uids, draws, budget, caches) -> (toks, cur, pos, alive,
    draws, budget, caches)``. Params and caches keep the engine's derived
    layouts — the caches spec appearing in BOTH positions is what lets the
    engine donate argument 7 and have XLA alias the pool in place across
    the whole K-step scan. The (B,)-shaped per-row carries (and the (B, K)
    token output) ride replicated: a few hundred bytes, not worth a
    collective, and the host reads them whole at the drain point.
    """
    in_sh = (param_sh, None, None, None, None, None, None, cache_sh)
    out_sh = (None, None, None, None, None, None, cache_sh)
    return in_sh, out_sh
