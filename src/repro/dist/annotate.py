"""Activation-sharding constraints (GSPMD hints) + launcher-set axis registry.

The launcher (dryrun / tests / train driver) declares which mesh axes carry
the batch dim via ``set_batch_axes``; model code then calls ``constrain_batch``
/ ``constrain_vocab`` at residual-stream and logit boundaries. Outside a
``set_mesh`` context (single-device reference paths) every constraint is a
no-op, so the same model code traces on one device and on a mesh.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import compat

Axes = Union[None, str, Tuple[str, ...]]

BATCH_AXES: Axes = None       # mesh axes sharding the batch dim
FSDP_AXIS: Optional[str] = None   # axis weights' embed dim is FSDP-sharded on
VOCAB_AXIS: str = "model"     # TP axis the vocab/logit dim stays sharded on


def set_batch_axes(axes: Axes, fsdp_axis: Optional[str] = None,
                   vocab_axis: str = "model") -> None:
    """Process-global launch declaration (trace-time, like ``flags.UNROLL``)."""
    global BATCH_AXES, FSDP_AXIS, VOCAB_AXIS
    BATCH_AXES = tuple(axes) if isinstance(axes, list) else axes
    FSDP_AXIS = fsdp_axis
    VOCAB_AXIS = vocab_axis


def _flat(axes: Axes) -> Tuple[str, ...]:
    if axes is None:
        return ()
    return axes if isinstance(axes, tuple) else (axes,)


def _usable(mesh, axes: Axes, dim: int) -> bool:
    names = _flat(axes)
    if not names or not all(a in mesh.shape for a in names):
        return False
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return dim % n == 0


def _constrain(x, spec: P):
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(compat.active_mesh(), spec))


def constrain_batch(x):
    """Keep dim 0 (batch) sharded over the declared batch axes."""
    mesh = compat.active_mesh()
    if mesh is None or not _usable(mesh, BATCH_AXES, x.shape[0]):
        return x
    return _constrain(x, P(BATCH_AXES, *([None] * (x.ndim - 1))))


def constrain_replicated(x):
    """Force a full replication boundary (explicit all-gather).

    Used where the 0.4.x SPMD partitioner miscompiles an op combination on a
    TP-sharded dim — e.g. split+concat over a sharded head_dim (rope) returns
    wrong values; gathering first sidesteps it (serving admission path, where
    the gathered chunk K/V are a few tokens wide). No-op off-mesh.
    """
    mesh = compat.active_mesh()
    if mesh is None:
        return x
    return _constrain(x, P(*([None] * x.ndim)))


def constrain_vocab(x):
    """Keep the trailing (vocab) dim TP-sharded — the chunked cross-entropy
    relies on this so GSPMD never replicates the (B, C, V) logit tile."""
    mesh = compat.active_mesh()
    if mesh is None or not _usable(mesh, VOCAB_AXIS, x.shape[-1]):
        return x
    lead = BATCH_AXES if _usable(mesh, BATCH_AXES, x.shape[0]) else None
    return _constrain(x, P(lead, *([None] * (x.ndim - 2)), VOCAB_AXIS))
