"""Pallas TPU kernel: blocked online-softmax (flash) attention.

Supports causal masking, sliding windows, gemma-style logit softcap, GQA via
index-mapped KV heads, and the Pliant *KV-block perforation* knob: with
``kv_keep_stride = p`` > 1 the kernel skips off-diagonal KV blocks unless
``(i - j) % p == 0``, cutting attention FLOPs and KV HBM traffic — the TPU
lowering of the paper's loop perforation applied to the attention loop.

Grid: (batch, q_heads, q_blocks, kv_blocks); kv innermost (sequential) with
running max / sum-exp / output accumulator in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, n_k: int, causal: bool, window: int,
            cap: float, stride: int, scale: float, n_kv: int):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # static-ish block skip condition evaluated on traced program ids:
    # diagonal + previous block always run; older blocks run at `stride`.
    run = jnp.bool_(True)
    if causal:
        run &= j * bk < (i + 1) * bq
    if window:
        run &= (i * bq - (j + 1) * bk) < window
    if stride > 1:
        near = (i * bq - j * bk) <= 2 * bq
        run &= near | ((i - (j * bk) // bq) % stride == 0)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if cap:
            s = cap * jnp.tanh(s / cap)
        q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        if n_k * bk > n_kv:          # padded ragged KV tail: mask it out
            mask &= k_pos < n_kv
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = (acc_ref[...] * alpha
                        + jax.lax.dot_general(
                            p.astype(v_ref.dtype), v_ref[0, 0],
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    @pl.when(j == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "cap", "kv_keep_stride", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    cap: float = 0.0, kv_keep_stride: int = 1,
                    bq: int = 128, bk: int = 128, interpret: bool = False):
    """q: (B,H,Sq,hd); k/v: (B,KVH,Skv,hd); returns (B,H,Sq,hd).

    Ragged sequence lengths (``Sq``/``Skv`` not multiples of the block size)
    are padded up to the block grid and masked: padded KV columns are
    excluded from every softmax row (explicitly for the tail block, by
    causality for the rest) and padded query rows are sliced off the output
    — no silent miscompute on the final partial block."""
    B, H, Sq, hd = q.shape
    _, KVH, Skv, _ = k.shape
    rep = H // KVH
    bq, bk = min(bq, Sq), min(bk, Skv)
    pad_q, pad_k = -Sq % bq, -Skv % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sqp, Skvp = Sq + pad_q, Skv + pad_k
    grid = (B, H, Sqp // bq, Skvp // bk)
    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, n_k=Skvp // bk, causal=causal, window=window,
        cap=cap, stride=kv_keep_stride, scale=hd ** -0.5, n_kv=Skv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq] if pad_q else out
