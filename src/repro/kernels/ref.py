"""Pure-jnp oracles for every Pallas kernel. These are the ground truth the
kernel tests assert against, and the CPU fallback paths used by the dry-run."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------- int8 matmul ----

def quantize_rowwise(x, axis=-1):
    """Symmetric int8 quantization with per-row (last-axis-reduced) scales."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def int8_matmul_ref(x_q, x_scale, w_q, w_scale, out_dtype=jnp.bfloat16):
    """x_q: (M,K) int8, x_scale: (M,1) f32; w_q: (K,N) int8, w_scale: (1,N)."""
    acc = jax.lax.dot_general(
        x_q, w_q, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(out_dtype)


def quantized_matmul_ref(x, w, out_dtype=None):
    """End-to-end W8A8 dynamic-quantized matmul (arbitrary leading dims)."""
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x_q, x_s = quantize_rowwise(x2)
    w_q, w_s = quantize_rowwise(w, axis=0)
    y = int8_matmul_ref(x_q, x_s, w_q, w_s, out_dtype)
    return y.reshape(lead + (w.shape[-1],))


# ------------------------------------------------------- flash attention ----

def mha_ref(q, k, v, *, causal=True, window=0, cap=0.0):
    """Naive masked attention oracle. q: (B,H,Sq,hd), k/v: (B,KVH,Skv,hd).

    GQA: q head h reads kv head h // (H // KVH).
    """
    B, H, Sq, hd = q.shape
    KVH = k.shape[1]
    rep = H // KVH
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    if cap:
        s = cap * jnp.tanh(s / cap)
    qp = jnp.arange(Sq)[:, None] + (k.shape[2] - Sq)   # align ends (decode ok)
    kp = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((Sq, k.shape[2]), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(q.dtype), v)


# ---------------------------------------------------------- Mamba2 SSD ----

def ssd_ref(x, dt, a, b, c, *, d_skip=None):
    """Naive per-token SSD recurrence oracle (fp32 state).

    x: (B,S,H,P); dt: (B,S,H) (already softplus'd); a: (H,) negative;
    b, c: (B,S,N) (single group, broadcast over heads). Returns (B,S,H,P).
    """
    Bsz, S, H, P = x.shape
    N = b.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf, cf = b.astype(jnp.float32), c.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp           # (B,H,P), (B,H), (B,N), (B,N)
        da = jnp.exp(dtt * a)           # (B,H)
        state = (state * da[..., None, None]
                 + (dtt[..., None] * xt)[..., None] * bt[:, None, None, :])
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    s0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(
        step, s0,
        (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
         bf.transpose(1, 0, 2), cf.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2, 3)
    if d_skip is not None:
        y = y + d_skip.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype)


def ssd_chunked_ref(x, dt, a, b, c, *, chunk=64, d_skip=None,
                    return_state=False, init_state=None):
    """Chunked (state-space-duality) jnp implementation — the algorithm the
    Pallas kernel implements; also the model's CPU/dry-run path.

    ``return_state=True`` additionally returns the final (B,H,P,N) state —
    used by serving prefill to hand off into incremental decode.
    ``init_state`` seeds the recurrence with an existing (B,H,P,N) state so a
    prompt can be consumed in chunks (serving chunked-prefill admission)."""
    Bsz, S, H, P = x.shape
    N = b.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    xf = x.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    bf = b.astype(jnp.float32).reshape(Bsz, nc, Q, N)
    cf = c.astype(jnp.float32).reshape(Bsz, nc, Q, N)
    la = dtf * a                                     # (B,nc,Q,H) log-decay
    cum = jnp.cumsum(la, axis=2)                     # inclusive
    total = cum[:, :, -1:, :]                        # (B,nc,1,H)
    # the big rank-5 intra-chunk operands are cast to the INPUT dtype (bf16
    # in production): decay/mask/dt chains fuse into a single low-precision
    # write instead of fp32, halving SSD HBM traffic (EXPERIMENTS.md §Perf
    # zamba2 iteration); fp32 is kept for cumsum, the state scan, and all
    # matmul ACCUMULATORS (preferred_element_type below).
    cdt = x.dtype
    # intra-chunk: y_t += sum_{i<=t} exp(cum_t - cum_i) dt_i (C_t.B_i) x_i
    # NOTE: expressed as two-operand einsums (batched matmuls) — 3-operand
    # forms made XLA materialize a rank-6 (B,nc,Q,K,H,P) intermediate
    # (EXPERIMENTS.md §Perf: 154 GiB peak, 4x FLOP inflation; fixed here).
    g = jnp.einsum("bcqn,bckn->bcqk", cf, bf,
                   preferred_element_type=jnp.float32)   # (B,nc,Q,Q)
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H) t,i
    mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
    m = jnp.where(mask[None, None, :, :, None], jnp.exp(dec), 0.0)
    w = (g[..., None] * m * dtf[:, :, None, :, :]).astype(cdt)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", w, xf.astype(cdt),
                         preferred_element_type=jnp.float32)
    # chunk states: S_c = exp(total) S_{c-1} + sum_i exp(total-cum_i) dt_i x_i B_i
    wi = jnp.exp(total - cum) * dtf                  # (B,nc,Q,H)
    s_in = jnp.einsum("bcqhp,bcqn->bchpn",
                      (xf * wi[..., None]).astype(cdt), bf.astype(cdt),
                      preferred_element_type=jnp.float32)

    # chunk-state recurrence as a STATIC python loop (nc is static, the body
    # is a few elementwise ops): a lax.scan here made the dry-run accounting
    # lie — XLA counts a while body once regardless of trips, and the body is
    # so small that the 2-point unroll probe measured loop-shuttle fusion
    # noise (a NEGATIVE byte marginal) instead of body cost. Fully static,
    # every chunk body is counted exactly in the base compile.
    decay = jnp.exp(total[:, :, 0, :])               # (B,nc,H)
    s = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
         else init_state.astype(jnp.float32))
    enters = []
    for ci in range(nc):
        enters.append(s)
        s = s * decay[:, ci, :, None, None] + s_in[:, ci]
    s_final = s
    s_enter = jnp.stack(enters, axis=1)              # (B,nc,H,P,N)
    y_state = jnp.einsum("bcqn,bchpn->bcqhp", cf.astype(cdt),
                         s_enter.astype(cdt),
                         preferred_element_type=jnp.float32) \
        * jnp.exp(cum)[..., None]
    y = (y_intra + y_state).reshape(Bsz, S, H, P)
    if d_skip is not None:
        y = y + d_skip.astype(jnp.float32)[None, None, :, None] * \
            x.astype(jnp.float32)
    if return_state:
        return y.astype(x.dtype), s_final
    return y.astype(x.dtype)
