"""Pallas API drift shim.

jax renamed ``jax.experimental.pallas.tpu.TPUCompilerParams`` to
``CompilerParams`` (and back-dated deprecation): the pinned jax 0.4.37 only
has the old name, current jax only the new one. Every kernel imports the
class from here so the rename is absorbed in one place.
"""
from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or _pltpu.TPUCompilerParams
