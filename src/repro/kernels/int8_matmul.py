"""Pallas TPU kernel: W8A8 int8 matmul with per-row / per-column scales.

The Pliant *lower-precision* knob lowered to the MXU: int8 operands halve the
HBM traffic of weight streaming vs bf16 and run on the MXU's int8 path.
Blocked (bm x bk) @ (bk x bn) with an fp32 VMEM accumulator carried across the
K grid dimension; scales applied once on the final K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _kernel(x_ref, xs_ref, w_ref, ws_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 x int8 -> int32 partial products on the MXU
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * xs_ref[...] * ws_ref[...]
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "interpret"))
def int8_matmul(x_q, x_scale, w_q, w_scale, *, bm: int = 128, bn: int = 128,
                bk: int = 512, out_dtype=jnp.bfloat16, interpret: bool = False):
    """x_q: (M,K) int8; x_scale: (M,1) f32; w_q: (K,N) int8; w_scale: (1,N)."""
    M, K = x_q.shape
    _, N = w_q.shape
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_q, x_scale, w_q, w_scale)
