"""Pallas TPU kernel: Mamba2 chunked SSD (state-space duality) scan.

TPU-native adaptation: instead of the GPU warp-level scan, the sequence is
split into MXU-sized chunks; within a chunk the recurrence is expressed as two
dense matmuls (the "duality"), and the (P x N) running state is carried across
chunks in a VMEM scratch accumulator over a sequential grid dimension.

Grid: (B, H, n_chunks) — chunks innermost/sequential per (batch, head).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state_ref, *, q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)           # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)         # (Q, 1) -> (Q,)
    dt = dt[:, 0]
    a = a_ref[0]                                  # scalar A_h (negative)
    b = b_ref[0].astype(jnp.float32)              # (Q, N)
    c = c_ref[0].astype(jnp.float32)              # (Q, N)

    la = dt * a                                   # (Q,) log decay
    cum = jnp.cumsum(la)                          # inclusive
    total = cum[-1]
    # intra-chunk: (C B^T ∘ decay ∘ causal) @ (dt*x)
    g = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q,Q)
    dec = cum[:, None] - cum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    w = jnp.where(tri, g * jnp.exp(dec), 0.0)
    y = jax.lax.dot_general(w, dt[:, None] * x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: y += exp(cum) * (C @ S_enter^T);   S_enter: (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, state_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0, 0] = y.astype(o_ref.dtype)
    # state update: S = exp(total) S + (w_i * x)^T @ B, w_i = exp(total-cum)*dt
    wi = (jnp.exp(total - cum) * dt)[:, None]     # (Q,1)
    state_ref[...] = (state_ref[...] * jnp.exp(total)
                      + jax.lax.dot_general(
                          wi * x, b, (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b, c, *, chunk: int = 128, interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); a: (H,); b,c: (B,S,N). Returns (B,S,H,P).

    D-skip (y += D*x) is applied by the caller (cheap elementwise).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    # layout: head-major so one (b,h) owns a contiguous chunk stream
    xh = x.transpose(0, 2, 1, 3)                  # (B,H,S,P)
    dth = dt.transpose(0, 2, 1)[..., None]        # (B,H,S,1)
    out = pl.pallas_call(
        functools.partial(_kernel, q=Q),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda i, h, ci: (i, h, ci, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda i, h, ci: (i, h, ci, 0)),
            pl.BlockSpec((1,), lambda i, h, ci: (h,)),
            pl.BlockSpec((1, Q, N), lambda i, h, ci: (i, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda i, h, ci: (i, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda i, h, ci: (i, h, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xh, dth, a.astype(jnp.float32), b, c)
    return out.transpose(0, 2, 1, 3)
