"""Pallas TPU kernel: fused paged-attention decode (vLLM-style).

One query token per batch slot attends over its block table's K/V pages
**in place**: the grid runs (slot, kv_head, logical_page) with the page dim
innermost (sequential), the block table and per-slot positions are scalar-
prefetched so each page's BlockSpec index map streams the *physical* page
HBM -> VMEM directly, and an online-softmax accumulator in VMEM scratch
folds pages as they arrive. The dense ``(B, S_max, G, hd)`` gather buffer of
the reference path never exists, so per-step decode HBM traffic scales with
LIVE pages instead of slots x max_len.

Dead traffic is skipped at two levels:

* **index map** — unmapped block entries already point at the reserved null
  page 0; the map also redirects pages wholly past the query position
  (speculatively-reserved decode pages from grouped admission) and, with
  ``window`` > 0, pages wholly below the local-attention band. Consecutive
  grid steps that map the same page elide the re-fetch, so skipped pages
  cost (at most) one null-page DMA.
* **``@pl.when`` body guard** — null/out-of-band/future pages skip the MXU
  work entirely; partial pages are masked per-entry by the page's ``ppos``
  row (position -1 = empty, plus causal/window masking), exactly mirroring
  the reference ``models.attention._gather_pages`` validity.

``kv_scale`` > 0 fuses int8 -> fp dequantization into the page load (the
``kv_quant`` serving knob): quantized K/V pages stream as int8 and are
scaled in VMEM, never round-tripping through an fp32 HBM buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _kernel(block_ref, pos_ref, q_ref, k_ref, v_ref, ppos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, page: int, n_m: int, window: int,
            kv_scale: float, cap: float, scale: float):
    b = pl.program_id(0)
    m = pl.program_id(2)          # logical page (sequential)

    @pl.when(m == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pid = block_ref[b, m]
    pos = pos_ref[b]
    run = pid != 0                               # unmapped -> null page
    run &= m * page <= pos                       # page starts past the query
    if window:
        run &= (m + 1) * page - 1 > pos - window  # wholly below the band

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (R, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)    # (P, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if kv_scale:                                 # fused int8 dequant
            k = k * kv_scale
            v = v * kv_scale
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if cap:
            s = cap * jnp.tanh(s / cap)
        kv_pos = ppos_ref[...]                       # (1, P)
        valid = (kv_pos >= 0) & (kv_pos <= pos)
        if window:
            valid &= kv_pos > pos - window
        s = jnp.where(valid, s, NEG_INF)             # (R, P)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = (acc_ref[...] * alpha
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    @pl.when(m == n_m - 1)
    def _finish():
        # all-masked slots (inactive decode rows) leave l == 0: emit zeros
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_attention_impl(q, kp, vp, ppos, block, position, *, window: int = 0,
                         kv_scale: float = 0.0, cap: float = 0.0,
                         interpret: bool = False):
    """Fused paged decode attention (unjitted body).

    q: (B, G, R, hd) — current token's queries, grouped by KV head;
    kp/vp: (n_pages, P, G, hd) physical page pools (int8 when ``kv_scale``);
    ppos: (n_pages, P) absolute positions (-1 empty); block: (B, M) int32
    physical page ids (0 = unmapped); position: (B,) absolute query position.
    Returns (B, G, R, hd) in q.dtype.

    Use ``paged_attention`` (the jitted wrapper) from op-level code; this
    raw body exists so ``models.attention`` can call the kernel INSIDE a
    ``shard_map`` region with per-shard (rebased) block tables — a nested
    jit there would re-trace per shard for nothing.
    """
    B, G, R, hd = q.shape
    n_pages, P = ppos.shape
    M = block.shape[1]
    block = block.astype(jnp.int32)
    position = position.astype(jnp.int32)

    def _qo_map(b, g, m, block_ref, pos_ref):
        return (b, g, 0, 0)

    def _page_map(b, g, m, block_ref, pos_ref):
        pid = block_ref[b, m]
        # redirect dead pages to the null page: the fetch aliases page 0
        # (elided when consecutive) instead of streaming a page the body
        # guard would ignore anyway. Dead = wholly past the query position
        # (grouped admission speculatively maps a request's projected decode
        # pages up front — still empty, never attended) or, with a window,
        # wholly below the local-attention band.
        dead = m * P > pos_ref[b]
        if window:
            dead |= (m + 1) * P - 1 <= pos_ref[b] - window
        pid = jnp.where(dead, 0, pid)
        return (pid, 0, 0, 0)

    def _kv_map(b, g, m, block_ref, pos_ref):
        pid = _page_map(b, g, m, block_ref, pos_ref)[0]
        return (pid, 0, g, 0)

    def _ppos_map(b, g, m, block_ref, pos_ref):
        pid = _page_map(b, g, m, block_ref, pos_ref)[0]
        return (pid, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, G, M),
        in_specs=[
            pl.BlockSpec((1, 1, R, hd), _qo_map),
            pl.BlockSpec((1, P, 1, hd), _kv_map),
            pl.BlockSpec((1, P, 1, hd), _kv_map),
            pl.BlockSpec((1, P), _ppos_map),
        ],
        out_specs=pl.BlockSpec((1, 1, R, hd), _qo_map),
        scratch_shapes=[
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _kernel, page=P, n_m=M, window=window, kv_scale=kv_scale, cap=cap,
        scale=hd ** -0.5)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block, position, q, kp, vp, ppos)


paged_attention = functools.partial(jax.jit, static_argnames=(
    "window", "kv_scale", "cap", "interpret"))(paged_attention_impl)


def page_hbm_bytes(page_size: int, n_kv_heads: int, head_dim: int, *,
                   kv_bytes: int = 4) -> int:
    """HBM bytes one live page streams through the fused kernel: K + V
    entries at the cache dtype width plus the int32 ``ppos`` row."""
    return 2 * page_size * n_kv_heads * head_dim * kv_bytes + 4 * page_size


def decode_hbm_bytes(live_pages: int, page_size: int, n_kv_heads: int,
                     head_dim: int, *, kv_bytes: int = 4, batch: int = 1,
                     n_heads: int = 0, q_bytes: int = 4,
                     max_pages: int = 0) -> int:
    """Per-step attention HBM bytes of the fused paged decode: every live
    page streamed once (each KV head's slice exactly once), plus the query/
    output vectors and the scalar-prefetched tables (the full (B, max_pages)
    block table + the (B,) positions). This is the kernel's cost model —
    O(live pages), not O(slots x max_len) — used by the explorer's decode
    pricing and the kernel benchmark's bytes-moved accounting."""
    nh = n_heads or n_kv_heads
    qo = 2 * batch * nh * head_dim * q_bytes
    tables = batch * 4 * (max_pages + 1)        # block rows + positions, int32
    return live_pages * page_hbm_bytes(page_size, n_kv_heads, head_dim,
                                       kv_bytes=kv_bytes) + qo + tables


def sharded_decode_hbm_bytes(live_pages: int, page_size: int,
                             n_kv_heads: int, head_dim: int, *,
                             n_shards: int = 1, kv_bytes: int = 4,
                             batch: int = 1, n_heads: int = 0,
                             q_bytes: int = 4, max_pages: int = 0) -> int:
    """PER-DEVICE attention HBM bytes of the shard_map'd fused decode under
    slot-affinity placement: each device runs the kernel over only its own
    slots' block tables, so it streams ceil(live/n_shards) pages for
    ceil(batch/n_shards) query rows (balanced placement — the allocator pins
    slot s to shard s*n_shards//batch_slots). The per-device traffic scales
    with live pages per shard, NOT slots x max_len — the acceptance metric
    of the sharded kernel path."""
    return decode_hbm_bytes(
        -(-live_pages // n_shards), page_size, n_kv_heads, head_dim,
        kv_bytes=kv_bytes, batch=-(-batch // n_shards), n_heads=n_heads,
        q_bytes=q_bytes, max_pages=max_pages)
