"""Ring-attention sequence-parallel chunked prefill.

One ``shard_map`` region wraps the blocked online-softmax flash cell: each
shard keeps its query rows *resident* while the K/V context (plus its
absolute positions) rotates around the ring via ``jax.lax.ppermute``. The
online-softmax state (m, l, acc) is carried across ring hops exactly the way
``kernels.flash_attention`` carries it across KV blocks — the per-hop Pallas
kernel below IS that kernel with the scratch state promoted to pallas-call
operands/outputs so a hop can resume where the previous one stopped.

Masking is *explicit-position* based (absolute ``q_pos`` / ``kv_pos``, -1 =
empty), never iota-derived, which makes correctness layout-invariant: any
permutation of the sequence dims preserves every (q, kv) pair's mask, only
the fp accumulation order changes. That freedom buys the two scheduling
tricks:

* **striped causal layout** — causal chunks assign query rows round-robin
  (row ``i`` -> shard ``i % n``) so every shard sees the same mix of early
  and late positions and the ring stays load-balanced (striped attention);
* **whole-hop skipping** — a hop whose visiting K/V shard is entirely in
  the future of every resident query (causal) or entirely behind the
  attention band (window mode, contiguous layout) is skipped with a
  ``lax.cond`` around the whole pallas call; inside a running hop the same
  position bounds skip individual (q-block, kv-block) tiles.

The per-device cost model at the bottom is what the explorer/roofline price
admission with and what ``benchmarks/kernel_bench.py`` persists: resident
queries and the initial K/V shard split ``n_shards`` ways; rotating tiles
are assumed to stay VMEM-resident between hops (a few MB per hop at 32k),
so the ring moves ICI wire bytes, not HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import PartitionSpec as P

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30
_BIG = 2 ** 30


def _hop_kernel(q_ref, k_ref, v_ref, qp_ref, kvp_ref, mi_ref, li_ref, ai_ref,
                mo_ref, lo_ref, ao_ref, m_s, l_s, a_s, *,
                bq: int, bk: int, n_k: int, window: int, cap: float,
                kv_scale: float, scale: float):
    """One ring hop: flash_attention._kernel with carried (m, l, acc) state
    entering as operands and leaving as outputs, and explicit-position
    masking instead of iota (the layout may be striped and the context may
    contain holes)."""
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_s[...] = mi_ref[0, 0]
        l_s[...] = li_ref[0, 0]
        a_s[...] = ai_ref[0, 0]

    qpos = qp_ref[...].reshape(bq, 1)
    kpos = kvp_ref[...].reshape(1, bk)
    q_ok, kv_ok = qpos >= 0, kpos >= 0
    # tile-level skip from position bounds (striped-attention block skip)
    q_max = jnp.max(jnp.where(q_ok, qpos, -1))
    kv_min = jnp.min(jnp.where(kv_ok, kpos, _BIG))
    run = jnp.any(kv_ok) & jnp.any(q_ok) & (kv_min <= q_max)
    if window:
        q_min = jnp.min(jnp.where(q_ok, qpos, _BIG))
        kv_max = jnp.max(jnp.where(kv_ok, kpos, -1))
        run &= kv_max > q_min - window

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        if kv_scale:
            k = k * kv_scale
            v = v * kv_scale
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if cap:
            s = cap * jnp.tanh(s / cap)
        mask = kv_ok & q_ok & (kpos <= qpos)
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_s[...], l_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        # rows still fully masked have m_new == NEG_INF and s - m_new == 0;
        # the mask (not the exp) must zero them or they'd accumulate 1s
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_s[...] = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
        m_s[...] = m_new
        a_s[...] = (a_s[...] * alpha
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))

    @pl.when(j == n_k - 1)
    def _finish():
        mo_ref[0, 0] = m_s[...]
        lo_ref[0, 0] = l_s[...]
        ao_ref[0, 0] = a_s[...]


def _hop(qf, kf, vf, qp, kvp, m, l, acc, *, window: int, cap: float,
         kv_scale: float, interpret: bool, bq: int = 128, bk: int = 128):
    """Advance the online-softmax state by one hop's K/V tile.

    qf: (B, H, Cl, hd); kf/vf: (B, KVH, Ll, hd) at storage dtype; qp: (B,
    Cl); kvp: (B, Ll); m/l: (B, H, Cl, 1) f32; acc: (B, H, Cl, hd) f32.
    Shapes are pre-padded to block multiples by the caller."""
    B, H, Cl, hd = qf.shape
    _, KVH, Ll, _ = kf.shape
    rep = H // KVH
    bq, bk = min(bq, Cl), min(bk, Ll)
    grid = (B, H, Cl // bq, Ll // bk)
    kernel = functools.partial(
        _hop_kernel, bq=bq, bk=bk, n_k=Ll // bk, window=window, cap=cap,
        kv_scale=kv_scale, scale=hd ** -0.5)
    q_spec = pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0))
    kv_spec = pl.BlockSpec((1, 1, bk, hd),
                           lambda b, h, i, j, rep=rep: (b, h // rep, j, 0))
    ml_spec = pl.BlockSpec((1, 1, bq, 1), lambda b, h, i, j: (b, h, i, 0))
    f32 = jnp.float32
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec,
                  pl.BlockSpec((1, bq), lambda b, h, i, j: (b, i)),
                  pl.BlockSpec((1, bk), lambda b, h, i, j: (b, j)),
                  ml_spec, ml_spec, q_spec],
        out_specs=[ml_spec, ml_spec, q_spec],
        out_shape=[jax.ShapeDtypeStruct(m.shape, f32),
                   jax.ShapeDtypeStruct(l.shape, f32),
                   jax.ShapeDtypeStruct(acc.shape, f32)],
        scratch_shapes=[pltpu.VMEM((bq, 1), f32),
                        pltpu.VMEM((bq, 1), f32),
                        pltpu.VMEM((bq, hd), f32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf, qp, kvp, m, l, acc)


def _pad_tail(x, axis: int, to: int, fill):
    pad = -x.shape[axis] % to
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def ring_chunk_attention(q, k, v, q_pos, kv_pos, *, mesh, plan, window: int = 0,
                         cap: float = 0.0, kv_scale: float = 0.0,
                         interpret: bool = False):
    """Sequence-parallel attention of one admission chunk over its context.

    q: (B, C, G, R, hd) resident queries; k/v: (B, L, G, hd) the chunk's
    full visible context (cache + in-chunk entries) at storage dtype (int8
    when ``kv_scale`` > 0 — dequantized per hop inside the kernel); q_pos:
    (B, C) absolute positions; kv_pos: (B, L) absolute positions with -1
    marking empty/unmapped entries. Masking is causal (kv <= q) plus the
    sliding-window band when ``window`` > 0, identical to the unsharded
    ``_sdpa`` admission cells. Returns (B, C, G, R, hd) in q's dtype.

    ``plan`` is a ``dist.sharding.PrefillPlan``; the sequence dims of q and
    k/v split over ``plan.seq_axis`` and K/V tiles rotate ``plan.n_shards -
    1`` times. Runs the Pallas hop kernel (interpret mode off-TPU)."""
    B, C, G, R, hd = q.shape
    L = k.shape[1]
    n, ax = plan.n_shards, plan.seq_axis
    g_ax = (plan.kv_head_axis
            if plan.kv_head_axis and G % mesh.shape[plan.kv_head_axis] == 0
            else None)
    q = _pad_tail(q, 1, n, 0)
    q_pos = _pad_tail(q_pos, 1, n, -1)
    k = _pad_tail(k, 1, n, 0)
    v = _pad_tail(v, 1, n, 0)
    kv_pos = _pad_tail(kv_pos, 1, n, -1)
    Cp = q.shape[1]
    inv = None
    if window == 0 and n > 1:
        # striped causal layout: shard d gets query rows d, d+n, d+2n, ...
        stripe = np.concatenate([np.arange(d, Cp, n) for d in range(n)])
        inv = np.argsort(stripe)
        q, q_pos = q[:, stripe], q_pos[:, stripe]

    def region(q_l, k_l, v_l, qp_l, kvp_l):
        B_, Cl, G_l, R_, hd_ = q_l.shape
        H_l = G_l * R_
        qf = q_l.transpose(0, 2, 3, 1, 4).reshape(B_, H_l, Cl, hd_)
        kf = k_l.transpose(0, 2, 1, 3)
        vf = v_l.transpose(0, 2, 1, 3)
        # pad per-shard lengths to kernel block multiples ONCE; the padded
        # K/V buffers ride the ring (all shards symmetric), padded rows are
        # position -1 (masked) and sliced off after the final hop
        bq, bk = min(128, Cl), min(128, kf.shape[2])
        qf = _pad_tail(qf, 2, bq, 0)
        qp_l = _pad_tail(qp_l, 1, bq, -1)
        kf = _pad_tail(kf, 2, bk, 0)
        vf = _pad_tail(vf, 2, bk, 0)
        kvp_l = _pad_tail(kvp_l, 1, bk, -1)
        Clp = qf.shape[2]
        m = jnp.full((B_, H_l, Clp, 1), NEG_INF, jnp.float32)
        l = jnp.zeros((B_, H_l, Clp, 1), jnp.float32)
        acc = jnp.zeros((B_, H_l, Clp, hd_), jnp.float32)
        qv = qp_l >= 0
        q_max = jnp.max(jnp.where(qv, qp_l, -1))
        q_min = jnp.min(jnp.where(qv, qp_l, _BIG))
        ring = [(i, (i + 1) % n) for i in range(n)]
        for hop in range(n):
            kvv = kvp_l >= 0
            kv_min = jnp.min(jnp.where(kvv, kvp_l, _BIG))
            kv_max = jnp.max(jnp.where(kvv, kvp_l, -1))
            # whole-hop skip: this K/V shard entirely empty / in the future
            # (causal) or entirely behind the window band
            run = jnp.any(kvv) & (kv_min <= q_max)
            if window:
                run &= kv_max > q_min - window

            def _go(ops):
                m_, l_, a_, kf_, vf_, kvp_ = ops
                return _hop(qf, kf_, vf_, qp_l, kvp_, m_, l_, a_,
                            window=window, cap=cap, kv_scale=kv_scale,
                            interpret=interpret)

            m, l, acc = jax.lax.cond(run, _go, lambda ops: ops[:3],
                                     (m, l, acc, kf, vf, kvp_l))
            if hop != n - 1:
                kf = jax.lax.ppermute(kf, ax, ring)
                vf = jax.lax.ppermute(vf, ax, ring)
                kvp_l = jax.lax.ppermute(kvp_l, ax, ring)
        o = (acc / jnp.maximum(l, 1e-30))[:, :, :Cl]
        o = o.reshape(B_, G_l, R_, Cl, hd_).transpose(0, 3, 1, 2, 4)
        return o.astype(q_l.dtype)

    from repro.dist import compat
    q_spec = P(None, ax, g_ax, None, None)
    kv_spec = P(None, ax, g_ax, None)
    p_spec = P(None, ax)
    # pin the operands REPLICATED before the shard_map boundary: the 0.4.x
    # partitioner miscompiles the reshape/stripe-gather/concat chain feeding
    # this region when it also owns the reshard into the ring layout (wrong
    # values, same hazard as the pre-rope gather in models.attention) —
    # forcing the producers to materialize replicated values leaves shard_map
    # a plain local slice
    rep = jax.sharding.NamedSharding(mesh, P())
    q = jax.lax.with_sharding_constraint(q, rep)
    k = jax.lax.with_sharding_constraint(k, rep)
    v = jax.lax.with_sharding_constraint(v, rep)
    q_pos = jax.lax.with_sharding_constraint(q_pos, rep)
    kv_pos = jax.lax.with_sharding_constraint(kv_pos, rep)
    out = compat.shard_map(
        region, mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec, p_spec, p_spec),
        out_specs=q_spec, check_vma=False)(q, k, v, q_pos, kv_pos)
    if inv is not None:
        out = out[:, inv]
    return out[:, :C]


# ------------------------------------------------- per-device cost account --

def prefill_attn_flops(chunk_len: int, kv_len: int, n_heads: int,
                       head_dim: int) -> float:
    """Attention FLOPs of one admission chunk: QK^T + PV over the full
    visible context (4 * C * L * H * hd). Masking skips roughly half under
    causality; this is the dense upper bound both paths share, so ratios
    between layouts are exact."""
    return 4.0 * chunk_len * kv_len * n_heads * head_dim


def sharded_prefill_attn_flops(chunk_len: int, kv_len: int, n_heads: int,
                               head_dim: int, *, n_shards: int) -> float:
    """Per-DEVICE ring FLOPs: each shard's resident C/n queries visit the
    whole context across the ring's n hops — 1/n_shards of the total."""
    return prefill_attn_flops(math.ceil(chunk_len / n_shards), kv_len,
                              n_heads, head_dim)


def prefill_hbm_bytes(chunk_len: int, kv_len: int, n_kv_heads: int,
                      head_dim: int, *, n_heads: int, kv_bytes: int = 4,
                      q_bytes: int = 4) -> int:
    """HBM traffic of one chunk's attention: read Q + write O (full heads),
    read K + V once (kv heads), plus the int32 position lanes. Scores never
    touch HBM (online softmax in VMEM)."""
    qo = 2 * chunk_len * n_heads * head_dim * q_bytes
    kv = 2 * kv_len * n_kv_heads * head_dim * kv_bytes
    pos = 4 * (chunk_len + kv_len)
    return qo + kv + pos


def sharded_prefill_hbm_bytes(chunk_len: int, kv_len: int, n_kv_heads: int,
                              head_dim: int, *, n_shards: int, n_heads: int,
                              kv_bytes: int = 4, q_bytes: int = 4) -> int:
    """Per-DEVICE ring HBM bytes: the single-device model applied to one
    shard's resident queries and initial K/V shard. Rotating tiles stay
    VMEM-resident between hops (ICI wire, not HBM), so the whole account
    splits n_shards ways."""
    return prefill_hbm_bytes(math.ceil(chunk_len / n_shards),
                             math.ceil(kv_len / n_shards), n_kv_heads,
                             head_dim, n_heads=n_heads, kv_bytes=kv_bytes,
                             q_bytes=q_bytes)
