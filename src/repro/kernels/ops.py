"""Jit'd public wrappers for the Pallas kernels with platform dispatch.

On TPU the Pallas kernels run natively; on CPU (this container, and the
dry-run's 512-way host platform) the pure-jnp references lower instead, so
``lower().compile()`` works everywhere and kernels are validated via
``interpret=True`` in tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.ssd_scan import ssd_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quantized_matmul(x, w):
    """W8A8 dynamic-quantized matmul (the Pliant lower-precision knob)."""
    if _on_tpu():
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        x_q, x_s = ref.quantize_rowwise(x2)
        w_q, w_s = ref.quantize_rowwise(w, axis=0)
        y = int8_matmul(x_q, x_s, w_q, w_s, out_dtype=x.dtype)
        return y.reshape(lead + (w.shape[-1],))
    return ref.quantized_matmul_ref(x, w)


def bf16_matmul(x, w):
    return jnp.einsum("...k,kn->...n", x, w)


def matmul(precision: str):
    """Matmul dispatch by approximation precision: 'bf16' | 'int8'."""
    if precision == "int8":
        return quantized_matmul
    return bf16_matmul


def flash(q, k, v, *, causal=True, window=0, cap=0.0, kv_keep_stride=1):
    """Flash attention: Pallas on TPU, naive jnp oracle elsewhere."""
    if _on_tpu():
        return flash_attention(q, k, v, causal=causal, window=window,
                               cap=cap, kv_keep_stride=kv_keep_stride)
    return ref.mha_ref(q, k, v, causal=causal, window=window, cap=cap)


def ssd(x, dt, a, b, c, *, chunk=128, d_skip=None):
    """Mamba2 SSD scan: Pallas on TPU, chunked jnp elsewhere."""
    if _on_tpu():
        y = ssd_scan(x, dt, a, b, c, chunk=chunk)
        if d_skip is not None:
            y = (y.astype(jnp.float32)
                 + d_skip.astype(jnp.float32)[None, None, :, None]
                 * x.astype(jnp.float32)).astype(x.dtype)
        return y
    return ref.ssd_chunked_ref(x, dt, a, b, c, chunk=chunk, d_skip=d_skip)
