"""Deterministic synthetic token pipeline: seeded, host-shardable, with
double-buffered background prefetch.

The stream has learnable structure (a seeded Markov chain over the vocab plus
copy motifs) so short training runs show real loss movement — required for
the quality oracle that measures approximation-variant inaccuracy.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_states: int = 64          # Markov states
    copy_period: int = 16       # every k-th token repeats token k-8 back


class SyntheticLM:
    """Seeded Markov-chain token source, shardable by (host_id, n_hosts)."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        root = np.random.default_rng(cfg.seed)
        # shared model of the "language": state transition + emission tables
        self.trans = root.dirichlet(np.ones(cfg.n_states) * 0.2,
                                    size=cfg.n_states)
        emis = root.dirichlet(np.ones(min(cfg.vocab_size, 512)) * 0.1,
                              size=cfg.n_states)
        self.emit_support = root.choice(
            cfg.vocab_size, size=(cfg.n_states, emis.shape[1]), replace=True)
        self.emis = emis

    def batch(self, step: int) -> np.ndarray:
        """(local_batch, seq_len + 1) int32, deterministic in (step, host)."""
        cfg = self.cfg
        out = np.empty((self.local_batch, cfg.seq_len + 1), np.int32)
        for i in range(self.local_batch):
            seq_id = step * cfg.global_batch + self.host_id * self.local_batch + i
            rng = np.random.default_rng((cfg.seed, seq_id))
            state = int(rng.integers(cfg.n_states))
            toks = np.empty(cfg.seq_len + 1, np.int32)
            for t in range(cfg.seq_len + 1):
                if cfg.copy_period and t % cfg.copy_period == 0 and t >= 8:
                    toks[t] = toks[t - 8]           # copy motif
                else:
                    e = rng.choice(self.emis.shape[1], p=self.emis[state])
                    toks[t] = self.emit_support[state, e]
                state = rng.choice(cfg.n_states, p=self.trans[state])
            out[i] = toks
        return out


class Prefetcher:
    """Background-thread double buffering over any step->batch function."""

    def __init__(self, fetch, start_step: int = 0, depth: int = 2):
        self._fetch = fetch
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            item = self._fetch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, item), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
