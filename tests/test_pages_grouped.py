"""Grouped/speculative page allocation and the watermark replenisher.

Admission maps a request's projected decode pages in the SAME free-list
transaction as its prompt pages (all-or-nothing, falling back to
prompt-only under pressure); copy-on-write prefix pins survive the
speculative reservation even when the allocation evicts the index; and the
background watermark reservation never strands a page."""
import pytest

from repro.serve.pages import PagePool, PageSpec


def test_grouped_admit_maps_decode_pages_up_front():
    pool = PagePool(PageSpec(page_size=4, n_pages=32, max_pages=8),
                    batch_slots=2)
    plan = pool.admit(0, list(range(13)), "tag", reserve_tokens=12)
    # 13 prompt tokens = 4 pages; +12 projected decode tokens -> 7 pages
    assert plan is not None and plan.reserved_pages == 3
    assert len(pool.slot_pages[0]) == 7
    assert pool.stats["grouped_admissions"] == 1
    assert pool.stats["grouped_pages"] == 3
    # the decode hot loop finds every projected page already mapped: no
    # per-page-crossing allocation, no block-table re-push
    for pos in (13, 16, 20, 24):
        assert pool.ensure_decode_page(0, pos) is False
    pool.assert_consistent()
    # reserved pages are freed with the slot like any private page
    pool.free_slot(0)
    assert pool.used == 0
    pool.assert_consistent()


def test_grouped_reservation_capped_by_block_table():
    """The group never projects past max_pages — the block table row is the
    hard ceiling, not a reason to fail admission."""
    pool = PagePool(PageSpec(page_size=4, n_pages=32, max_pages=5),
                    batch_slots=1)
    plan = pool.admit(0, list(range(13)), "tag", reserve_tokens=64)
    assert plan is not None and plan.reserved_pages == 1    # 5 - 4 prompt
    assert len(pool.slot_pages[0]) == 5
    pool.assert_consistent()


def test_alloc_n_is_all_or_nothing():
    pool = PagePool(PageSpec(page_size=4, n_pages=8, max_pages=8),
                    batch_slots=2)                          # 7 usable
    assert pool.admit(0, list(range(8)), "tag") is not None  # 2 pages
    before = (list(pool.free), list(pool.ref), pool.used,
              pool.stats["allocs"], list(pool.scrub_pending))
    assert pool._alloc_n(6) is None                         # only 5 free
    after = (list(pool.free), list(pool.ref), pool.used,
              pool.stats["allocs"], list(pool.scrub_pending))
    assert after == before                                  # exact undo
    pool.assert_consistent()
    got = pool._alloc_n(5)                                  # boundary fits
    assert got is not None and len(got) == 5
    assert pool.used == 7


def test_grouped_falls_back_to_prompt_only_under_pressure():
    pool = PagePool(PageSpec(page_size=4, n_pages=8, max_pages=8),
                    batch_slots=2)                          # 7 usable
    assert pool.admit(1, list(range(100, 112)), "tag") is not None  # 3 pages
    plan = pool.admit(0, list(range(12)), "tag", reserve_tokens=16)
    # full group (7 pages) no longer fits; the 3 prompt pages do
    assert plan is not None and plan.reserved_pages == 0
    assert len(pool.slot_pages[0]) == 3
    assert pool.stats["grouped_fallbacks"] == 1
    assert pool.stats["grouped_admissions"] == 0
    # decode growth falls back to the incremental path and still works
    assert pool.ensure_decode_page(0, 12) is True
    pool.assert_consistent()


def test_cow_pins_survive_speculative_reservation():
    """Under budget pressure the speculative allocation's LRU loop may
    evict the very prefix entry the admission just matched; the hit pages
    must already carry the slot's pin so the copy-on-write mapping stays
    live while the reservation allocates past them."""
    pool = PagePool(PageSpec(page_size=4, n_pages=16, max_pages=8),
                    batch_slots=2, reclaim_quantum=9)       # 15 usable
    prompt_a = list(range(13))                              # 4 pages
    prompt_b = list(range(100, 113))
    for slot, prompt in ((0, prompt_a), (1, prompt_b)):
        pool.admit(slot, prompt, "tag")
        pool.register_prefix(slot, prompt, "tag", 12)       # pins pages 1..3
        pool.free_slot(slot)                                # index-pinned only
    assert pool.used == 6
    pool.set_reclaimed(1)                   # limit 15-9=6 == used: squeezed
    plan = pool.admit(0, prompt_a, "tag", reserve_tokens=8)
    # hit the 3-page shared prefix, then allocate tail + 2 reserved pages
    # through the pressure loop: it evicts prompt_a's entry first (LRU-
    # oldest) — the hit pages survive on the slot's pin — then prompt_b's
    assert plan is not None
    assert plan.shared_tokens == 12 and plan.reserved_pages == 2
    assert not pool.index
    mapped = [int(p) for p in pool.blocks[0] if p]
    assert len(mapped) == 6
    assert not (set(mapped) & set(pool.free)), (mapped, list(pool.free))
    assert not (set(mapped) & set(pool.scrub_pending))
    assert all(pool.ref[p] == 1 for p in mapped)
    pool.assert_consistent()


def test_watermark_replenish_keeps_headroom_without_stranding():
    pool = PagePool(PageSpec(page_size=4, n_pages=16, max_pages=8),
                    batch_slots=3)                          # 15 usable
    prompts = [list(range(i * 100, i * 100 + 13)) for i in range(3)]
    for slot, prompt in enumerate(prompts):
        plan = pool.admit(slot, prompt, "tag")              # 4 pages each
        for b in plan.register:
            pool.register_prefix(slot, prompt, "tag", b)
    pool.free_slot(1)
    pool.free_slot(2)                       # slots 1/2 now index-pinned only
    assert pool.used == 10 and len(pool.free) == 5
    # headroom (5) below the low watermark: evict LRU entries off the
    # admission path — slot 0's entries are slot-pinned (evicting them
    # frees nothing), slot 1's actually release pages — until high
    evicted = pool.replenish(low=6, high=8)
    assert evicted > 0
    assert pool.stats["replenish_evictions"] == evicted
    assert min(len(pool.free), pool.limit - pool.used) >= 6
    pool.assert_consistent()
    # above the watermark: a no-op, not an eviction treadmill
    assert pool.replenish(low=6, high=8) == 0
    # the live slot's pages were untouchable throughout
    assert len(pool.slot_pages[0]) == 4
    pool.free_slot(0)
    while pool.index:                       # drain: nothing may be stranded
        pool.replenish(low=pool.spec.usable, high=pool.spec.usable)
    assert pool.used == 0 and len(pool.free) == pool.spec.usable
    pool.assert_consistent()


def test_replenish_measures_headroom_under_reclaim_limit():
    """Headroom is allocatable room under the RECLAIM limit, not the raw
    free-list length: after a shrink, eviction keeps restoring room (by
    lowering ``used``) even while free pages are plentiful."""
    pool = PagePool(PageSpec(page_size=4, n_pages=24, max_pages=4),
                    batch_slots=2, reclaim_quantum=5)       # 23 usable
    for slot, base in ((0, 0), (1, 100)):
        prompt = list(range(base, base + 13))
        pool.admit(slot, prompt, "tag")
        pool.register_prefix(slot, prompt, "tag", 12)
        pool.free_slot(slot)
    assert pool.used == 6 and len(pool.free) == 17
    pool.set_reclaimed(3)                       # limit 23 - 15 = 8
    # 17 raw free pages, but allocatable room is only limit - used = 2:
    # replenish must evict (the LRU entry, freeing its 3 pages) anyway
    evicted = pool.replenish(low=3, high=4)
    assert evicted == 1
    assert pool.used == 3 and len(pool.index) == 1
    assert min(len(pool.free), pool.limit - pool.used) >= 3
    pool.assert_consistent()
    while pool.index:                           # drain: nothing stranded
        pool.replenish(low=pool.spec.usable, high=pool.spec.usable)
    assert pool.used == 0 and len(pool.free) == pool.spec.usable
    pool.assert_consistent()
