"""Model-level attention: chunked-causal / banded-window paths vs the naive
oracle, KV-cache decode equivalence, ring-buffer windows, KV quantization."""
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ref
from repro.models import attention as A

CFG = get_config("phi4-mini-3.8b-smoke")


def _params(cfg, key=0):
    from repro.models.common import init_params
    return init_params(A.attn_specs(cfg), jax.random.PRNGKey(key),
                       jnp.float32)


def _oracle(params, x, cfg, *, causal=True, window=0):
    hd = cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(*x.shape[:2], cfg.n_heads, hd)
    k = (x @ params["wk"]).reshape(*x.shape[:2], cfg.n_kv_heads, hd)
    v = (x @ params["wv"]).reshape(*x.shape[:2], cfg.n_kv_heads, hd)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    from repro.models.common import apply_rope
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    o = ref.mha_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                    v.transpose(0, 2, 1, 3), causal=causal, window=window,
                    cap=cfg.attn_softcap)
    o = o.transpose(0, 2, 1, 3).reshape(*x.shape[:2], cfg.q_dim)
    return o @ params["wo"]


@pytest.mark.parametrize("q_chunk", [8, 16, 64])
def test_causal_chunked_matches_oracle(q_chunk):
    params = _params(CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, CFG.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(64), (2, 64))
    got = A.attention(params, x, pos, CFG, mode="causal", q_chunk=q_chunk)
    want = _oracle(params, x, CFG)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [8, 16, 32])
def test_banded_window_matches_oracle(window):
    cfg = dataclasses.replace(CFG, window=window)
    params = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(64), (2, 64))
    got = A.attention(params, x, pos, cfg, mode="window")
    want = _oracle(params, x, cfg, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_softcap_changes_output():
    cfg = dataclasses.replace(CFG, attn_softcap=5.0)
    params = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(32), (1, 32))
    capped = A.attention(params, x, pos, cfg, mode="causal")
    plain = A.attention(params, x, pos, CFG, mode="causal")
    assert float(jnp.max(jnp.abs(capped - plain))) > 1e-5
    want = _oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(capped), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_kv_perforation_reduces_context():
    params = _params(CFG)
    S = 64
    x = jax.random.normal(jax.random.PRNGKey(4), (1, S, CFG.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S), (1, S))
    precise = A.attention(params, x, pos, CFG, q_chunk=8)
    perf = A.attention(params, x, pos, CFG, q_chunk=8, kv_keep_stride=2)
    # first two chunks identical (diagonal + previous always kept)
    np.testing.assert_allclose(np.asarray(perf[:, :16]),
                               np.asarray(precise[:, :16]), atol=1e-5)
    assert float(jnp.max(jnp.abs(perf - precise))) > 1e-6


def test_decode_ring_buffer_window():
    """Ring cache smaller than sequence: decode == windowed full attention."""
    W = 16
    cfg = dataclasses.replace(CFG, window=W)
    params = _params(cfg)
    S = 48
    x = jax.random.normal(jax.random.PRNGKey(5), (2, S, cfg.d_model)) * 0.3
    want = _oracle(params, x, cfg, window=W)
    cache = A.init_cache(cfg, 2, W, dtype=jnp.float32)
    outs = []
    for t in range(S):
        o, cache = A.decode_attention(
            params, x[:, t:t + 1], jnp.full((2,), t, jnp.int32), cache, cfg,
            window=W)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_decode_kv_quantization_close():
    params = _params(CFG)
    S = 24
    x = jax.random.normal(jax.random.PRNGKey(6), (1, S, CFG.d_model)) * 0.1
    cache_p = A.init_cache(CFG, 1, S, dtype=jnp.float32)
    cache_q = A.init_cache(CFG, 1, S, dtype=jnp.float32, quantized=True)
    for t in range(S):
        pos = jnp.full((1,), t, jnp.int32)
        op, cache_p = A.decode_attention(params, x[:, t:t+1], pos, cache_p,
                                         CFG)
        oq, cache_q = A.decode_attention(params, x[:, t:t+1], pos, cache_q,
                                         CFG, kv_scale=0.01)
    rel = float(jnp.linalg.norm(oq - op) / jnp.linalg.norm(op))
    assert rel < 0.05, rel
    assert cache_q.k.dtype == jnp.int8
