"""Config registry: exact assigned dims, analytic param counts, cell grid."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, all_cells, get_config
from repro.models import api

EXPECTED_BILLIONS = {       # within documented substitutions (DESIGN.md)
    "zamba2-2.7b": (1.5, 2.8), "gemma3-12b": (10.5, 13),
    "mistral-large-123b": (115, 130), "phi4-mini-3.8b": (3.4, 4.3),
    "gemma2-27b": (24, 30), "whisper-large-v3": (1.2, 2.4),
    "paligemma-3b": (2.0, 3.2), "mamba2-780m": (0.7, 0.9),
    "olmoe-1b-7b": (6.0, 7.5), "moonshot-v1-16b-a3b": (15, 30),
}

ASSIGNED = {
    "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
                        d_ff=10240, vocab_size=32000),
    "gemma3-12b": dict(n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
                       d_ff=15360, vocab_size=262144),
    "mistral-large-123b": dict(n_layers=88, d_model=12288, n_heads=96,
                               n_kv_heads=8, d_ff=28672, vocab_size=32768),
    "phi4-mini-3.8b": dict(n_layers=32, d_model=3072, n_heads=24,
                           n_kv_heads=8, d_ff=8192, vocab_size=200064),
    "gemma2-27b": dict(n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
                       d_ff=36864, vocab_size=256000),
    "whisper-large-v3": dict(n_layers=32, d_model=1280, n_heads=20,
                             n_kv_heads=20, d_ff=5120, vocab_size=51866),
    "paligemma-3b": dict(n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
                         d_ff=16384, vocab_size=257216),
    "mamba2-780m": dict(n_layers=48, d_model=1536, d_ff=0, vocab_size=50280),
    "olmoe-1b-7b": dict(n_layers=16, d_model=2048, n_heads=16,
                        n_kv_heads=16, d_ff=1024, vocab_size=50304),
    "moonshot-v1-16b-a3b": dict(n_layers=48, d_model=2048, n_heads=16,
                                n_kv_heads=16, d_ff=1408, vocab_size=163840),
}


@pytest.mark.parametrize("name", list(ARCHS))
def test_assigned_dims(name):
    cfg = ARCHS[name]
    for field, val in ASSIGNED[name].items():
        assert getattr(cfg, field) == val, (name, field)


@pytest.mark.parametrize("name", list(ARCHS))
def test_param_count_in_band(name):
    lo, hi = EXPECTED_BILLIONS[name]
    n = ARCHS[name].param_count() / 1e9
    assert lo <= n <= hi, (name, n)


def test_moe_knobs():
    assert ARCHS["olmoe-1b-7b"].moe.n_experts == 64
    assert ARCHS["olmoe-1b-7b"].moe.top_k == 8
    assert ARCHS["moonshot-v1-16b-a3b"].moe.top_k == 6


def test_cell_grid():
    cells = list(all_cells())
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    assert len(runnable) == 34
    skipped = {(a.name, s.name) for a, s, ok, _ in cells if not ok}
    assert all(s == "long_500k" for _, s in skipped)
    assert ("mamba2-780m", "long_500k") not in skipped
    assert ("zamba2-2.7b", "long_500k") not in skipped
    assert ("gemma3-12b", "long_500k") not in skipped
    assert ("gemma2-27b", "long_500k") not in skipped


@pytest.mark.parametrize("name", list(ARCHS))
def test_smoke_config_param_count_matches_init(name):
    cfg = get_config(name + "-smoke")
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    n = sum(x.size for x in jax.tree.leaves(params))
    assert n == cfg.param_count()


def test_shapes_registry():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].seq_len == 524288
