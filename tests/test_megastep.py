"""Megastep decode: the fused K-token dispatch must be a pure perf knob.

The contract is STREAM invariance, not host-numpy bit parity: greedy
megastep output must equal the per-step paged engine token-for-token across
all four cache families, and temperature output must be invariant in K
(the on-device sampler keys every draw by (seed, uid, draw_index), so the
megastep width cannot change the stream). EOS inside a megastep must stop
that row in-scan without corrupting siblings or the page pool, and buffer
donation must be verifiably ACTIVE (aliased executables + consumed inputs)
rather than silently dropped.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve.engine import Request, ServeEngine

ARCHS = ["phi4-mini-3.8b",     # MHA
         "zamba2-2.7b",        # hybrid attn/SSM (+shared)
         "mamba2-780m",        # pure SSM
         "gemma2-27b"]         # GQA + local attention

_PARAMS = {}


def setup(name):
    cfg = get_config(name + "-smoke")
    if name not in _PARAMS:
        _PARAMS[name] = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, _PARAMS[name]


def drive(cfg, params, prompts, max_new=5, *, slots=2, chunk=3, **kw):
    eng = ServeEngine(cfg, batch_slots=slots, max_len=64, params=params,
                      prefill_chunk=chunk, paged=True, page_size=4, **kw)
    reqs = [Request(i, prompt=list(p), max_new=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    eng.pool.assert_consistent()
    return [list(r.out) for r in reqs], eng


def prompts_for(cfg, n=5, length=7, seed=3):
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(1, cfg.vocab_size, length)))
            for _ in range(n)]


@pytest.mark.parametrize("name", ARCHS)
@pytest.mark.parametrize("k", [1, 4, 16])
def test_megastep_greedy_matches_per_step(name, k):
    """Greedy megastep(K) == the per-step paged engine, token for token,
    through multiple admission waves (5 requests / 2 slots)."""
    cfg, params = setup(name)
    prompts = prompts_for(cfg)
    base, _ = drive(cfg, params, prompts)
    out, eng = drive(cfg, params, prompts, megastep_k=k)
    assert out == base, (name, k, out, base)
    assert eng.decode_dispatches > 0
    # per-row accounting: megastep can only LOWER dispatches/token
    assert eng.row_dispatches / max(eng.row_tokens, 1) <= 1.0


@pytest.mark.parametrize("name", ["zamba2-2.7b", "gemma2-27b"])
def test_megastep_temperature_stream_invariant_in_k(name):
    """On-device temperature sampling: the (seed, uid, draw_index) fold-in
    stream makes the output independent of the megastep width — K=4 and
    K=1 megasteps must emit identical tokens (and a different seed must
    not)."""
    cfg, params = setup(name)
    prompts = prompts_for(cfg, n=4, length=6, seed=7)
    t1, _ = drive(cfg, params, prompts, max_new=6,
                  megastep_k=1, temperature=0.7, seed=11)
    t4, _ = drive(cfg, params, prompts, max_new=6,
                  megastep_k=4, temperature=0.7, seed=11)
    assert t1 == t4
    other, _ = drive(cfg, params, prompts, max_new=6,
                     megastep_k=4, temperature=0.7, seed=12)
    assert other != t4   # the seed actually feeds the stream


@pytest.mark.parametrize("name", ["phi4-mini-3.8b", "mamba2-780m"])
def test_eos_mid_megastep_frees_slot_without_corrupting_siblings(name):
    """A row hitting EOS inside a megastep stops emitting THERE (in-scan
    stop masking), its slot/pages are freed at the drain, and sibling rows
    decode on unperturbed — K=8 equals K=1 under the same eos_id."""
    cfg, params = setup(name)
    prompts = prompts_for(cfg, n=4, length=6, seed=7)
    base, _ = drive(cfg, params, prompts, max_new=6, megastep_k=1)
    # a token observed MID-output in the eos-free run becomes the stop id
    eos = base[0][2]
    e1, _ = drive(cfg, params, prompts, max_new=6, megastep_k=1, eos_id=eos)
    e8, eng = drive(cfg, params, prompts, max_new=6, megastep_k=8,
                    eos_id=eos)
    assert e1 == e8, (eos, e1, e8)
    assert any(o[-1] == eos and len(o) < 6 for o in e8), e8  # early stop
    assert all(o[-1] == eos or len(o) == 6 for o in e8), e8  # nothing past it
    assert eng.pool.slot_pages == [[] for _ in range(eng.batch_slots)]


def test_donation_active_in_compiled_megastep():
    """Donation is an executable property — assert the lowered megastep
    actually aliases input caches to output caches (alias_size > 0), and
    that disabling donation removes the aliasing."""
    from repro.train import step as step_mod
    cfg, params = setup("phi4-mini-3.8b")
    eng = ServeEngine(cfg, batch_slots=2, max_len=64, params=params,
                      prefill_chunk=3, paged=True, page_size=4, megastep_k=4)
    step = step_mod.make_paged_megastep(cfg, k=4, dynamic_scatter=True)
    B = eng.batch_slots
    zi = jnp.zeros((B,), jnp.int32)
    zb = jnp.zeros((B,), bool)
    args = (params, zi, zi, zb, zi, zi, zi, eng.caches)
    donated = jax.jit(step, donate_argnums=(7,)).lower(*args).compile()
    plain = jax.jit(step).lower(*args).compile()
    assert donated.memory_analysis().alias_size_in_bytes > 0
    assert plain.memory_analysis().alias_size_in_bytes == 0


def test_donation_consumes_stale_cache_references():
    """End-to-end: after a megastep the previous cache buffers are GONE —
    reading a stale reference raises, proving XLA reused the memory
    instead of double-buffering the KV pool."""
    cfg, params = setup("zamba2-2.7b")
    eng = ServeEngine(cfg, batch_slots=2, max_len=64, params=params,
                      prefill_chunk=3, paged=True, page_size=4, megastep_k=4)
    req = Request(0, prompt=prompts_for(cfg)[0], max_new=6)
    eng.submit(req)
    while not req.out:          # admit until the slot decodes
        eng.step()
    stale = eng.caches
    eng.step()                  # megastep consumes `stale`
    eng.run()
    assert req.done
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(jax.tree.leaves(stale)[0])


def test_donation_off_keeps_buffers_alive():
    """The escape hatch: donate=False engines never consume their inputs
    (tests/tools that hold cache references stay valid)."""
    cfg, params = setup("phi4-mini-3.8b")
    eng = ServeEngine(cfg, batch_slots=2, max_len=64, params=params,
                      prefill_chunk=3, paged=True, page_size=4,
                      megastep_k=4, donate=False)
    req = Request(0, prompt=prompts_for(cfg)[0], max_new=5)
    eng.submit(req)
    while not req.out:
        eng.step()
    stale = eng.caches
    eng.run()
    np.asarray(jax.tree.leaves(stale)[0])   # must NOT raise
    assert req.done


def test_per_uid_rng_streams_match_fresh_generators():
    """Regression for the cached per-uid numpy streams (`_rng_for`): the
    i-th draw for uid u must equal the i-th draw of a fresh
    default_rng((seed, uid)) — caching generators across calls must not
    advance or cross the streams."""
    cfg, params = setup("phi4-mini-3.8b")
    eng = ServeEngine(cfg, batch_slots=2, max_len=64, params=params,
                      prefill_chunk=3, paged=True, page_size=4,
                      temperature=0.8, seed=5)
    draws = {}
    for uid in (3, 9, 3, 9, 3):
        g = eng._rng_for(Request(uid, prompt=[1], max_new=1))
        draws.setdefault(uid, []).append(g.random())
    for uid, got in draws.items():
        fresh = np.random.default_rng((5, uid))
        want = [fresh.random() for _ in got]
        assert got == want, (uid, got, want)


def test_megastep_pipeline_survives_variant_swap():
    """Hot-swapping the variant mid-run (across the kv_quant cache-encoding
    boundary, the worst case) with a megastep IN FLIGHT: the executable
    table rebuilds per (variant, K), the rebuilt executable re-donates, and
    every request still completes with full-length output and a consistent
    pool."""
    from repro.approx.knobs import PRECISE, ApproxKnobs
    from repro.core.variants import Variant, VariantTable
    cfg, params = setup("gemma2-27b")
    table = VariantTable([Variant(PRECISE, 1.0, 0.0),
                          Variant(ApproxKnobs(kv_quant=True), 0.8, 0.01)])
    prompts = prompts_for(cfg, n=4, length=6)
    eng = ServeEngine(cfg, batch_slots=2, max_len=64, params=params,
                      prefill_chunk=3, paged=True, page_size=4,
                      megastep_k=8, table=table)
    reqs = [Request(i, prompt=list(p), max_new=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while not eng.idle:
        eng.step()
        steps += 1
        if steps == 4:
            eng.request_variant(1)
        assert steps < 500
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 6 for r in reqs)
    assert eng.active_variant == 1
    # the rebuilt table is keyed (variant, k) where k is clamped to the
    # longest remaining row budget — some variant-1 executable must exist
    assert any(v == 1 for (v, _) in eng._megasteps), eng._megasteps.keys()
    eng.pool.assert_consistent()


def test_explain_megastep_banner():
    cfg, params = setup("phi4-mini-3.8b")
    eng = ServeEngine(cfg, batch_slots=2, max_len=64, params=params,
                      paged=True, page_size=4, megastep_k=6)
    s = eng.explain_megastep()
    assert "6 tokens" in s and "donation ON" in s and "pipeline" in s
    assert "megastep scan" in eng.explain_dispatch()
    off = ServeEngine(cfg, batch_slots=2, max_len=64, params=params,
                      paged=True, page_size=4)
    assert "off" in off.explain_megastep()
    assert "megastep" not in off.explain_dispatch()
