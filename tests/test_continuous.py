"""Continuous batching: admissions opened mid-run interleave with live
decoders yet the paged engine stays token-identical to the dense ring
engine (greedy sampling and per-request PRNG streams make outputs
scheduling-invariant); the per-step prefill-chunk spend never exceeds the
QoS budget; and the batched sampler consumes exactly the same per-request
random streams as a one-row-at-a-time loop."""
import functools
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.monitor import LatencyMonitor
from repro.models import api
from repro.serve.engine import Request, ServeEngine

_PARAMS = {}


def setup(name):
    cfg = get_config(name + "-smoke")
    if name not in _PARAMS:
        _PARAMS[name] = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, _PARAMS[name]


@pytest.mark.parametrize("name", ["phi4-mini-3.8b",     # attention
                                  "zamba2-2.7b",        # hybrid
                                  "mamba2-780m",        # pure SSM
                                  "gemma2-27b"])        # local+global attn
def test_midrun_admission_interleaves_and_matches_dense(name):
    """Requests submitted while earlier ones are mid-decode are admitted
    into freed slots chunk-by-chunk BETWEEN decode steps (no wave barrier),
    with several admissions in flight at once — and every request's token
    stream equals the dense engine's."""
    cfg, params = setup(name)
    rng = np.random.default_rng(9)
    prompts = [list(rng.integers(1, cfg.vocab_size, 7)) for _ in range(5)]

    dense_eng = ServeEngine(cfg, batch_slots=3, max_len=64, params=params,
                            prefill_chunk=3, paged=False)
    dense_reqs = [Request(i, prompt=list(p), max_new=6)
                  for i, p in enumerate(prompts)]
    for r in dense_reqs:
        dense_eng.submit(r)
    dense_eng.run()

    eng = ServeEngine(cfg, batch_slots=3, max_len=64, params=params,
                      prefill_chunk=3, paged=True, page_size=4)
    reqs = [Request(i, prompt=list(p), max_new=6)
            for i, p in enumerate(prompts)]
    eng.submit(reqs[0])
    steps = 0
    while eng.slots[0] is None and steps < 50:   # request 0 reaches decode
        eng.step()
        steps += 1
    assert eng.slots[0] is reqs[0]
    for r in reqs[1:]:                           # arrive mid-run
        eng.submit(r)
    concurrent, interleaved = 0, False
    while not all(r.done for r in reqs) and steps < 500:
        eng.step()
        steps += 1
        live = any(s is not None for s in eng.slots)
        concurrent = max(concurrent, len(eng._admissions))
        interleaved |= bool(eng._admissions) and live
    assert all(r.done for r in reqs)
    # two free slots + four pending and a one-chunk budget (decoders live,
    # no runtime): admissions MUST have overlapped each other and decode
    assert concurrent >= 2, concurrent
    assert interleaved
    assert [r.out for r in reqs] == [r.out for r in dense_reqs]
    # property: no step spent more prefill chunks than its QoS budget, and
    # with live decoders and no monitor evidence the budget is exactly 1
    assert eng.step_admission_chunks
    assert all(used <= budget for used, budget in eng.step_admission_chunks)
    eng.pool.assert_consistent()


def _budget_harness(*, slots_live: int, cap: int = 4, guard: float = 0.25,
                    monitor=None):
    """``_chunk_budget`` reads only these engine fields — a stub avoids
    compiling a real engine per property-test example."""
    return SimpleNamespace(
        max_admission_chunks=cap, qos_guard=guard,
        slots=[object()] * slots_live + [None] * (4 - slots_live),
        runtime=None if monitor is None else SimpleNamespace(monitor=monitor))


def test_chunk_budget_guard_band():
    budget = ServeEngine._chunk_budget
    # no live decoder: burst regardless of monitor state
    assert budget(_budget_harness(slots_live=0)) == 4
    # live decoders, no runtime: no evidence -> one chunk per step
    assert budget(_budget_harness(slots_live=2)) == 1
    # abstaining monitor (below min_samples): still conservative
    mon = LatencyMonitor(qos_target_s=0.1, window=64, min_samples=4)
    assert budget(_budget_harness(slots_live=2, monitor=mon)) == 1
    # p99 comfortably inside the guard band (p99 <= 0.75 * target): burst
    mon.record_many([0.01] * 16)
    assert budget(_budget_harness(slots_live=2, monitor=mon)) == 4
    # p99 inside the target but INSIDE the guard band: back to one chunk
    hot = LatencyMonitor(qos_target_s=0.1, window=64, min_samples=4)
    hot.record_many([0.09] * 16)
    assert budget(_budget_harness(slots_live=2, monitor=hot)) == 1


@settings(max_examples=60, deadline=None)
@given(cap=st.integers(1, 8), guard=st.floats(0.0, 0.9),
       live=st.integers(0, 4), target_ms=st.floats(1.0, 100.0),
       lat_ms=st.floats(0.1, 200.0))
def test_chunk_budget_property(cap, guard, live, target_ms, lat_ms):
    """The budget is always in [1, cap]; it exceeds 1 ONLY when either no
    decoder is live or the observed p99 is inside the guard band."""
    mon = LatencyMonitor(qos_target_s=target_ms / 1e3, window=64,
                         min_samples=4)
    mon.record_many([lat_ms / 1e3] * 8)
    b = ServeEngine._chunk_budget(
        _budget_harness(slots_live=live, cap=cap, guard=guard, monitor=mon))
    assert 1 <= b <= max(1, cap)
    if b > 1:
        assert live == 0 or mon.p99() <= (1.0 - guard) * mon.qos_target_s


def _sampler(seed):
    eng = SimpleNamespace(temperature=1.0, seed=seed, _rngs={})
    eng._rng_for = functools.partial(ServeEngine._rng_for, eng)
    return eng


def test_batched_sampling_matches_per_row_loop():
    """The vectorized ``_sample_rows`` must consume exactly one draw per
    request from that request's own ``(seed, uid)`` stream — identical to
    sampling each row alone, across successive calls."""
    eng = _sampler(seed=7)
    rng = np.random.default_rng(0)
    reqs = [Request(uid, prompt=[1], max_new=4) for uid in (3, 11, 4, 8, 0)]
    batched = []
    logits = [rng.normal(size=(5, 33)).astype(np.float32) for _ in range(3)]
    for lg in logits:                            # three decode steps
        batched.append(ServeEngine._sample_rows(eng, lg, reqs))
    for i, r in enumerate(reqs):                 # one request at a time
        solo = _sampler(seed=7)
        for t, lg in enumerate(logits):
            tok = ServeEngine._sample_rows(solo, lg[i:i + 1], [r])
            assert int(tok[0]) == int(batched[t][i]), (r.uid, t)


def test_sampling_is_slot_assignment_invariant():
    """Continuous batching may land the same request in a different slot /
    batch row on every run; per-request PRNG keying makes the drawn token
    depend only on (seed, uid, draw index) — never on the row order."""
    rng = np.random.default_rng(1)
    logits = rng.normal(size=(5, 17)).astype(np.float32)
    reqs = [Request(uid, prompt=[1], max_new=1) for uid in (2, 9, 5, 0, 7)]
    base = ServeEngine._sample_rows(_sampler(seed=3), logits, reqs)
    perm = [4, 2, 0, 3, 1]
    shuf = ServeEngine._sample_rows(_sampler(seed=3), logits[perm],
                                    [reqs[i] for i in perm])
    for j, i in enumerate(perm):
        assert int(shuf[j]) == int(base[i])
    # a different engine seed draws a different stream (sanity)
    other = ServeEngine._sample_rows(_sampler(seed=4), logits, reqs)
    assert any(int(a) != int(b) for a, b in zip(base, other))
