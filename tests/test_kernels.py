"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles,
executed in Pallas interpret mode (the kernel body runs on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.ssd_scan import ssd_scan


@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (256, 512, 384),
                                   (128, 1024, 256)])
@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_int8_matmul_matches_ref(m, k, n, out_dtype):
    kx = jax.random.PRNGKey(0)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    xq, xs = ref.quantize_rowwise(x)
    wq, ws = ref.quantize_rowwise(w, axis=0)
    got = int8_matmul(xq, xs, wq, ws, out_dtype=out_dtype, interpret=True,
                      bk=256)
    want = ref.int8_matmul_ref(xq, xs, wq, ws, out_dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_int8_quantized_matmul_accuracy():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 512), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 256), jnp.float32)
    exact = x @ w
    approx = ref.quantized_matmul_ref(x, w, jnp.float32)
    rel = float(jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact))
    assert rel < 0.02, rel          # W8A8 error well under 2%


@pytest.mark.parametrize("kvh", [8, 2, 1])
@pytest.mark.parametrize("kwargs", [
    dict(causal=True), dict(causal=False),
    dict(causal=True, window=64), dict(causal=True, cap=30.0),
    dict(causal=True, window=128, cap=50.0),
])
def test_flash_attention_matches_oracle(kvh, kwargs):
    B, H, S, hd = 2, 8, 256, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, S, hd)) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(1), (B, kvh, S, hd)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(2), (B, kvh, S, hd))
    got = flash_attention(q, k, v, interpret=True, bq=64, bk=64, **kwargs)
    want = ref.mha_ref(q, k, v, **kwargs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    B, H, S, hd = 1, 4, 128, 64
    q = (jax.random.normal(jax.random.PRNGKey(0), (B, H, S, hd)) * 0.3
         ).astype(jnp.bfloat16)
    k = (jax.random.normal(jax.random.PRNGKey(1), (B, H, S, hd)) * 0.3
         ).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2),
                          (B, H, S, hd)).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, interpret=True, bq=64, bk=64)
    want = ref.mha_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_attention_kv_perforation_drops_blocks():
    """With stride p, off-diagonal KV blocks are skipped -> result differs
    from precise but matches a mask-equivalent oracle on kept blocks."""
    B, H, S, hd = 1, 2, 512, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, S, hd)) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, hd)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, hd))
    precise = flash_attention(q, k, v, interpret=True, bq=64, bk=64)
    perf = flash_attention(q, k, v, interpret=True, bq=64, bk=64,
                           kv_keep_stride=4)
    # differs (approximation happened) but stays finite and bounded
    assert float(jnp.max(jnp.abs(perf - precise))) > 1e-6
    assert bool(jnp.all(jnp.isfinite(perf)))
    # early rows (diagonal-only) are identical
    np.testing.assert_allclose(np.asarray(perf[:, :, :128]),
                               np.asarray(precise[:, :, :128]), atol=1e-6)


@pytest.mark.parametrize("shape", [(1, 64, 2, 16, 8), (2, 128, 3, 32, 16),
                                   (1, 256, 4, 64, 32)])
def test_ssd_scan_matches_naive(shape):
    B, S, H, P, N = shape
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (B, S, H)))
    a = -jnp.exp(jax.random.uniform(jax.random.PRNGKey(2), (H,)))
    b = jax.random.normal(jax.random.PRNGKey(3), (B, S, N)) * 0.5
    c = jax.random.normal(jax.random.PRNGKey(4), (B, S, N)) * 0.5
    want = ref.ssd_ref(x, dt, a, b, c)
    chunk = min(32, S)
    got_k = ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=True)
    got_c = ref.ssd_chunked_ref(x, dt, a, b, c, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssd_with_d_skip():
    B, S, H, P, N = 1, 64, 2, 16, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (B, S, H)))
    a = -jnp.exp(jax.random.uniform(jax.random.PRNGKey(2), (H,)))
    b = jax.random.normal(jax.random.PRNGKey(3), (B, S, N)) * 0.5
    c = jax.random.normal(jax.random.PRNGKey(4), (B, S, N)) * 0.5
    d = jnp.ones((H,))
    want = ref.ssd_ref(x, dt, a, b, c, d_skip=d)
    got = ref.ssd_chunked_ref(x, dt, a, b, c, chunk=16, d_skip=d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_bf16_inputs_close():
    """Production dtype path: bf16 operands with fp32 state/accumulators
    (EXPERIMENTS.md P9) stays within bf16-appropriate tolerance of the fp32
    naive recurrence."""
    B, S, H, P, N = 2, 128, 3, 32, 16
    x = (jax.random.normal(jax.random.PRNGKey(0), (B, S, H, P)) * 0.5
         ).astype(jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (B, S, H)))
    a = -jnp.exp(jax.random.uniform(jax.random.PRNGKey(2), (H,)))
    b = (jax.random.normal(jax.random.PRNGKey(3), (B, S, N)) * 0.5
         ).astype(jnp.bfloat16)
    c = (jax.random.normal(jax.random.PRNGKey(4), (B, S, N)) * 0.5
         ).astype(jnp.bfloat16)
    want = ref.ssd_ref(x.astype(jnp.float32), dt, a, b.astype(jnp.float32),
                       c.astype(jnp.float32))
    got = ref.ssd_chunked_ref(x, dt, a, b, c, chunk=32)
    rel = float(jnp.linalg.norm(got.astype(jnp.float32) - want)
                / jnp.linalg.norm(want))
    assert rel < 0.05, rel
