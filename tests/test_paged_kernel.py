"""Fused paged-attention decode kernel: interpret-mode parity against the
``_gather_pages`` reference path across arch families (full attention, GQA +
softcap + sliding window, hybrid shared-attention dims), ragged per-slot page
counts, partial last pages, and int8 KV — plus the ``active`` write-mask
contract the stall-free serving loop depends on."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as attn_mod
from repro.models.attention import KV_SCALE, PagedKVCache, quantize_kv
from repro.models.common import init_params

P = 4          # page size
M = 6          # block-table width (max logical pages)


def _setup(name, lengths, *, quantized=False, seed=0):
    """Random paged decode state: per-slot prompts of ``lengths`` tokens
    already resident (ragged page counts, partial last pages), the decode
    token landing at position ``lengths[b]``. Returns (cfg, params, x,
    position, cache)."""
    cfg = get_config(name + "-smoke")
    B = len(lengths)
    n_pages = 1 + B * M
    rng = np.random.default_rng(seed)
    hd, G = cfg.resolved_head_dim, cfg.n_kv_heads
    if quantized:
        kp = quantize_kv(jnp.asarray(
            rng.normal(size=(n_pages, P, G, hd)) * 0.3, jnp.float32))
        vp = quantize_kv(jnp.asarray(
            rng.normal(size=(n_pages, P, G, hd)), jnp.float32))
    else:
        kp = jnp.asarray(rng.normal(size=(n_pages, P, G, hd)) * 0.3,
                         jnp.float32)
        vp = jnp.asarray(rng.normal(size=(n_pages, P, G, hd)), jnp.float32)
    block = np.zeros((B, M), np.int32)
    ppos = np.full((n_pages, P), -1, np.int32)
    pid = 1
    for b, L in enumerate(lengths):
        for lp in range(-(-(L + 1) // P)):        # decode writes at pos L
            block[b, lp] = pid
            top = min(L, (lp + 1) * P)            # partial last page
            ppos[pid, : max(top - lp * P, 0)] = np.arange(lp * P, top)
            pid += 1
    params = init_params(attn_mod.attn_specs(cfg), jax.random.PRNGKey(1),
                         jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)) * 0.3, jnp.float32)
    position = jnp.asarray(np.asarray(lengths, np.int32))
    cache = PagedKVCache(kp, vp, jnp.asarray(ppos), jnp.asarray(block))
    return cfg, params, x, position, cache


@pytest.mark.parametrize("name", ["phi4-mini-3.8b",   # full attention (MHA)
                                  "gemma2-27b",       # GQA + softcap + local
                                  "zamba2-2.7b"])     # hybrid shared-attn dims
@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("quantized", [False, True])
def test_fused_matches_gather_reference(name, window, quantized):
    lengths = [0, 5, 9, 14]                 # ragged: 1..4 pages, partial tails
    cfg, params, x, position, cache = _setup(name, lengths,
                                             quantized=quantized)
    kv_scale = KV_SCALE if quantized else 0.0
    o_ref, c_ref = attn_mod.paged_decode_attention(
        params, x, position, cache, cfg, window=window, kv_scale=kv_scale,
        use_kernel=False)
    o_k, c_k = attn_mod.paged_decode_attention(
        params, x, position, cache, cfg, window=window, kv_scale=kv_scale,
        use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
    # the scatter side is shared code: caches must match EXACTLY
    for a, b in zip(c_ref, c_k):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_window_skips_out_of_band_pages():
    """With a window, the kernel must ignore pages wholly below the band —
    scrambling their contents must not change the output (the index map
    redirects them to the null page; the body guard skips them)."""
    cfg, params, x, position, cache = _setup("gemma2-27b", [15, 18])
    window = 6
    o1, _ = attn_mod.paged_decode_attention(
        params, x, position, cache, cfg, window=window, use_kernel=True,
        interpret=True)
    # pages 0..1 of each slot hold positions <= 11 <= min(pos) - window
    dead = np.asarray(cache.block[:, :2]).ravel()
    kp = cache.kp.at[jnp.asarray(dead)].set(1e3)
    vp = cache.vp.at[jnp.asarray(dead)].set(-1e3)
    o2, _ = attn_mod.paged_decode_attention(
        params, x, position, cache._replace(kp=kp, vp=vp), cfg,
        window=window, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_active_mask_blocks_inactive_writes(use_kernel):
    """The stall-free loop's contract: rows with ``active=False`` (slots
    mid-admission or empty) must not scatter their garbage token into the
    pool — pages AND ppos stay bit-identical for inactive rows."""
    cfg, params, x, position, cache = _setup("phi4-mini-3.8b", [6, 9])
    active = jnp.asarray(np.array([True, False]))
    _, c_new = attn_mod.paged_decode_attention(
        params, x, position, cache, cfg, active=active,
        use_kernel=use_kernel, interpret=use_kernel)
    # row 1's tail page (the write target) must be untouched
    tail1 = int(cache.block[1, 9 // P])
    np.testing.assert_array_equal(np.asarray(c_new.kp[tail1]),
                                  np.asarray(cache.kp[tail1]))
    np.testing.assert_array_equal(np.asarray(c_new.ppos[tail1]),
                                  np.asarray(cache.ppos[tail1]))
    # row 0's write DID land
    tail0 = int(cache.block[0, 6 // P])
    assert int(c_new.ppos[tail0, 6 % P]) == 6


@pytest.mark.parametrize("use_kernel", [False, True])
def test_speculative_future_pages_do_not_change_output(use_kernel):
    """Grouped admission maps a request's projected decode pages up front
    (scrubbed: ``ppos`` = -1). Decode output must be bit-identical whether
    or not those future pages are mapped, regardless of their K/V contents
    — the kernel's index map redirects wholly-future pages to the null
    page; the gather path masks their empty ``ppos`` rows."""
    lengths = [5, 9]
    cfg, params, x, position, cache = _setup("phi4-mini-3.8b", lengths)
    o1, _ = attn_mod.paged_decode_attention(
        params, x, position, cache, cfg, use_kernel=use_kernel,
        interpret=use_kernel)
    block = np.asarray(cache.block).copy()
    used = {int(p) for p in block.ravel()}
    fresh = [p for p in range(1, int(cache.kp.shape[0])) if p not in used]
    scramble = []
    for b, L in enumerate(lengths):
        for m in range(-(-(L + 1) // P), M):    # wholly past the query pos
            block[b, m] = scramble_pid = fresh.pop()
            scramble.append(scramble_pid)
    kp = cache.kp.at[jnp.asarray(scramble)].set(1e3)
    vp = cache.vp.at[jnp.asarray(scramble)].set(-1e3)
    c2 = cache._replace(kp=kp, vp=vp, block=jnp.asarray(block))
    o2, _ = attn_mod.paged_decode_attention(
        params, x, position, c2, cfg, use_kernel=use_kernel,
        interpret=use_kernel)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


@pytest.mark.parametrize("active_mask", [None, (True, False)])
def test_dyn_scatter_write_matches_one_hot(active_mask):
    """The dynamic-index cache write (single-device engines) must land the
    decode token bit-identically to the one-hot masked scatter on every
    LIVE page; inactive rows write only the never-read null page."""
    cfg, params, x, position, cache = _setup("phi4-mini-3.8b", [6, 9])
    active = (None if active_mask is None
              else jnp.asarray(np.asarray(active_mask)))
    o1, c1 = attn_mod.paged_decode_attention(
        params, x, position, cache, cfg, active=active, use_kernel=False)
    o2, c2 = attn_mod.paged_decode_attention(
        params, x, position, cache, cfg, active=active, use_kernel=False,
        dyn_scatter=True)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(c1.block), np.asarray(c2.block))
    # page 0 is the trash page: dyn-scatter parks masked rows there,
    # one-hot never touches it — both are fine, nothing ever reads it
    for a, b in ((c1.kp, c2.kp), (c1.vp, c2.vp), (c1.ppos, c2.ppos)):
        np.testing.assert_array_equal(np.asarray(a[1:]), np.asarray(b[1:]))


def test_mamba_decode_active_mask_preserves_state():
    from repro.models import mamba2
    cfg = get_config("mamba2-780m-smoke")
    params = init_params(mamba2.mamba_specs(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    cache = mamba2.init_mamba_cache(cfg, 2, jnp.float32)
    cache = mamba2.MambaCache(*(x + 0.5 for x in cache))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 1, cfg.d_model)),
                    jnp.float32)
    _, c_new = mamba2.mamba_decode(params, x, cache, cfg,
                                   active=jnp.asarray([False, True]))
    for old, new in zip(cache, c_new):
        np.testing.assert_array_equal(np.asarray(new[0]), np.asarray(old[0]))
        assert not np.array_equal(np.asarray(new[1]), np.asarray(old[1]))
