"""Batched prefill-with-cache-fill: the handoff caches must continue decode
exactly as a token-by-token warmup would, for every cache family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api, lm
from repro.serve.prefill import prefill_with_cache


@pytest.mark.parametrize("name", ["phi4-mini-3.8b", "gemma2-27b",
                                  "mamba2-780m", "zamba2-2.7b",
                                  "olmoe-1b-7b"])
def test_prefill_handoff_matches_decode_warmup(name):
    cfg = get_config(name + "-smoke")
    if cfg.moe is not None:
        # expert capacity must not bind: batched routing sees all tokens at
        # once while per-token warmup routes tiny batches — different drop
        # sets are expected behavior under tight capacity (see test_dist)
        import dataclasses
        from repro.configs.base import MoEConfig
        cfg = dataclasses.replace(
            cfg, moe=MoEConfig(cfg.moe.n_experts, cfg.moe.top_k,
                               capacity_factor=16.0))
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S, max_len = 2, 12, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)

    # reference: token-by-token decode warmup
    caches_ref = lm.init_caches(cfg, B, max_len, dtype=jnp.float32)
    step = jax.jit(lambda p, t, pos, c: lm.decode_step(p, t, pos, c, cfg))
    logits_ref = None
    for i in range(S):
        logits_ref, caches_ref = step(params, toks[:, i:i+1],
                                      jnp.full((B,), i, jnp.int32),
                                      caches_ref)

    # batched prefill
    logits_pf, caches_pf = jax.jit(
        lambda p, t: prefill_with_cache(p, t, cfg, max_len))(params, toks)
    np.testing.assert_allclose(np.asarray(logits_pf), np.asarray(logits_ref),
                               rtol=3e-3, atol=3e-3)

    # decode continues identically from both cache sets
    nxt = jnp.argmax(logits_pf, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    out_ref, _ = step(params, nxt, pos, caches_ref)
    out_pf, _ = step(params, nxt, pos, caches_pf)
    np.testing.assert_allclose(np.asarray(out_pf), np.asarray(out_ref),
                               rtol=3e-3, atol=3e-3)


def test_prefill_window_ring_layout():
    """Local-attention cache smaller than the prompt: only the last W tokens
    survive, and decode continues correctly through the ring."""
    import dataclasses
    cfg = dataclasses.replace(get_config("gemma2-27b-smoke"), window=8)
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S, max_len = 1, 16, 48
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    caches_ref = lm.init_caches(cfg, B, max_len, dtype=jnp.float32)
    step = jax.jit(lambda p, t, pos, c: lm.decode_step(p, t, pos, c, cfg))
    for i in range(S):
        logits_ref, caches_ref = step(params, toks[:, i:i+1],
                                      jnp.full((B,), i, jnp.int32),
                                      caches_ref)
    logits_pf, caches_pf = prefill_with_cache(params, toks, cfg, max_len)
    np.testing.assert_allclose(np.asarray(logits_pf), np.asarray(logits_ref),
                               rtol=3e-3, atol=3e-3)
    nxt = jnp.argmax(logits_pf, -1)[:, None].astype(jnp.int32)
    o1, _ = step(params, nxt, jnp.full((B,), S, jnp.int32), caches_ref)
    o2, _ = step(params, nxt, jnp.full((B,), S, jnp.int32), caches_pf)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1),
                               rtol=3e-3, atol=3e-3)
