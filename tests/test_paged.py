"""Paged cache subsystem: block-table engine equivalence, copy-on-write
prefix reuse, and the Pliant-reclaimable page pool.

The paged engine must reproduce the dense ring engine's greedy outputs
EXACTLY across the attention / local+global / hybrid / pure-SSM cache
families, including multi-wave slot reuse (stale-state hazards: reused
pages' positions, reused slots' Mamba state). A shared-prefix workload must
HIT the prefix index and skip the covered prefill chunks; a pool shrink /
regrow round-trip — manual and controller-driven — must never corrupt a
live request.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx.knobs import PRECISE, ApproxKnobs
from repro.configs import get_config
from repro.core.controller import ControllerConfig
from repro.core.monitor import LatencyMonitor
from repro.core.runtime import PliantRuntime
from repro.core.variants import Variant, VariantTable
from repro.launch.serve import serving_table
from repro.models import api
from repro.serve.engine import Request, ServeEngine

_PARAMS = {}


def setup(name):
    cfg = get_config(name + "-smoke")
    if name not in _PARAMS:
        _PARAMS[name] = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, _PARAMS[name]


def drive(cfg, params, prompts, max_new=5, *, paged, page_size=4, n_pages=0,
          slots=2, max_len=64, chunk=3, **kw):
    eng = ServeEngine(cfg, batch_slots=slots, max_len=max_len, params=params,
                      prefill_chunk=chunk, paged=paged, page_size=page_size,
                      n_pages=n_pages, **kw)
    reqs = [Request(i, prompt=list(p), max_new=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    return [r.out for r in reqs], eng


@pytest.mark.parametrize("name", ["phi4-mini-3.8b",     # attention
                                  "zamba2-2.7b",        # hybrid (+shared)
                                  "mamba2-780m",        # pure SSM
                                  "gemma2-27b"])        # local+global attn
def test_paged_matches_dense_engine(name):
    cfg, params = setup(name)
    rng = np.random.default_rng(3)
    # 5 requests through 2 slots: multiple admission waves reuse slots AND
    # (with the tight 16-page pool) recycle freed physical pages
    prompts = [list(rng.integers(1, cfg.vocab_size, 7)) for _ in range(5)]
    dense, _ = drive(cfg, params, prompts, paged=False)
    paged, eng = drive(cfg, params, prompts, paged=True, n_pages=16)
    assert paged == dense, (name, paged, dense)
    assert eng.pool.stats["frees"] > 0          # pages actually cycled
    assert eng.pool.used == 0 or eng.pool.index  # only prefix pins remain


@pytest.mark.parametrize("name", ["phi4-mini-3.8b", "zamba2-2.7b"])
def test_prefix_reuse_skips_chunks(name):
    """Shared-prompt traffic: later requests map the registered prefix pages
    copy-on-write and skip those prefill chunks entirely (SSM state restored
    from the boundary snapshot for hybrid archs) — with outputs still equal
    to the dense engine's token-by-token."""
    cfg, params = setup(name)
    rng = np.random.default_rng(7)
    prefix = list(rng.integers(1, cfg.vocab_size, 8))
    prompts = [prefix + list(rng.integers(1, cfg.vocab_size, 4))
               for _ in range(4)]
    prompts.append(list(prompts[0]))            # exact duplicate prompt
    dense, _ = drive(cfg, params, prompts, paged=False)
    paged, eng = drive(cfg, params, prompts, paged=True)
    assert paged == dense, (paged, dense)
    s = eng.pool.stats
    # requests 1-3 share the 8-token (2-page) prefix; request 4 additionally
    # matches request 0's full pages capped at len-1 -> still 8 tokens
    assert s["prefix_hits"] >= 4, s
    assert s["tokens_skipped"] >= 4 * 8, s
    # shared pages are refcounted, not copied: peak usage stays well under
    # 5 requests' worth of private pages (3 pages each + decode growth)
    assert s["peak_used"] < 5 * 3 + 3, s


def test_prefix_hit_runs_fewer_chunks():
    """A prefix hit must SKIP executable calls, not just relabel them."""
    cfg, params = setup("phi4-mini-3.8b")
    rng = np.random.default_rng(11)
    prompt = list(rng.integers(1, cfg.vocab_size, 9))
    eng = ServeEngine(cfg, batch_slots=1, max_len=64, params=params,
                      prefill_chunk=2, paged=True, page_size=4)
    calls = []
    orig = eng._prefill_exe

    def counting(C):
        calls.append(C)
        return orig(C)

    eng._prefill_exe = counting
    eng.submit(Request(0, prompt=list(prompt), max_new=2))
    eng.run()
    first = sum(calls)
    assert first == 9, calls                    # full prompt prefilled
    calls.clear()
    eng.submit(Request(1, prompt=list(prompt), max_new=2))
    eng.run()
    # 8 of 9 tokens (two full pages, capped at len-1) skipped on the hit
    assert sum(calls) == 1, calls


def test_pool_shrink_regrow_roundtrip():
    """A manual pool_pages shrink/regrow mid-decode never corrupts live
    requests: outputs stay equal to the dense engine's."""
    cfg, params = setup("zamba2-2.7b")
    rng = np.random.default_rng(5)
    prompts = [list(rng.integers(1, cfg.vocab_size, 7)) for _ in range(4)]
    dense, _ = drive(cfg, params, prompts, max_new=10, paged=False)
    eng = ServeEngine(cfg, batch_slots=2, max_len=64, params=params,
                      prefill_chunk=3, paged=True, page_size=4)
    reqs = [Request(i, prompt=list(p), max_new=10)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()                              # requests mid-decode
    eng.pool.set_reclaimed(eng.pool.max_quanta)
    for _ in range(3):
        eng.step()                              # decode under shrunk budget
    eng.pool.set_reclaimed(0)
    eng.run()
    assert [r.out for r in reqs] == dense
    assert eng.pool.stats["reclaim_events"] == 2
    log = eng.pool.stats["reclaim_log"]
    assert [e["action"] for e in log] == ["shrink", "grow"]


def test_admit_pins_hit_pages_before_alloc_can_evict():
    """Under budget pressure, admit's fresh-page allocation may LRU-evict
    the very prefix entry it just matched; the hit pages must be pinned by
    the slot FIRST so they are never freed/scrubbed/double-allocated while
    the admission maps them."""
    from repro.serve.pages import PagePool, PageSpec
    spec = PageSpec(page_size=4, n_pages=16, max_pages=4)   # usable: 15
    pool = PagePool(spec, batch_slots=2, reclaim_quantum=9)
    prompt_a = list(range(13))                              # 4 pages each
    prompt_b = list(range(100, 113))
    for slot, prompt in ((0, prompt_a), (1, prompt_b)):
        plan = pool.admit(slot, prompt, "tag")
        for b in plan.register:                             # entries at 4/8/12
            pool.register_prefix(slot, prompt, "tag", b)
        pool.free_slot(slot)                                # index-pinned only
    assert pool.used == 6                                   # 3 pages per prefix
    pool.set_reclaimed(1)      # limit 15-9 = 6 == used: nothing evicted YET
    # the hit entry (prompt_a, LRU-oldest) is evicted by _alloc's pressure
    # loop DURING this admission; its pages must already carry the slot's ref
    plan = pool.admit(0, prompt_a, "tag")
    assert plan is not None and plan.shared_tokens == 12
    assert not pool.index                                   # everything evicted
    mapped = [int(p) for p in pool.blocks[0] if p]
    assert len(mapped) == 4
    # every mapped page stayed live: none free, none awaiting a ppos scrub
    assert not (set(mapped) & set(pool.free)), (mapped, list(pool.free))
    assert not (set(mapped) & set(pool.scrub_pending))
    assert all(pool.ref[p] == 1 for p in mapped)
    # and a fresh _alloc never hands out a mapped page
    got = pool._alloc(for_live=True)
    assert got not in mapped


def test_blocked_admission_does_not_inflate_prefix_stats():
    """A pool-blocked request retried every engine step must not bump the
    hit/miss counters (BENCH_serve's prefix_hit_rate) until it commits."""
    from repro.serve.pages import PagePool, PageSpec
    spec = PageSpec(page_size=4, n_pages=8, max_pages=4)
    pool = PagePool(spec, batch_slots=2)
    assert pool.admit(0, list(range(13)), "tag") is not None
    pool.ensure_decode_page(0, 13)
    for _ in range(5):                          # retried while pool is full
        assert pool.admit(1, list(range(16)), "tag") is None
    assert pool.stats["blocked_admissions"] == 5
    assert pool.stats["prefix_hits"] + pool.stats["prefix_misses"] == 1


def test_never_fitting_prompt_raises_instead_of_spinning():
    """A prompt needing more pages than the pool owns must fail loudly at
    admission, not busy-spin run() through max_steps unserved."""
    from repro.serve.pages import PagePool, PageSpec
    pool = PagePool(PageSpec(page_size=4, n_pages=8, max_pages=16),
                    batch_slots=1)
    with pytest.raises(RuntimeError, match="pages but the pool has"):
        pool.admit(0, list(range(33)), "tag")       # 9 pages > 7 usable


def test_registration_bounded_by_max_register_pages():
    """Index growth and (hybrid) snapshot pauses are capped per prompt:
    boundaries past max_register_pages are not registered, and lookups
    still hit the capped depth."""
    from repro.serve.pages import PagePool, PageSpec
    pool = PagePool(PageSpec(page_size=4, n_pages=32, max_pages=8),
                    batch_slots=2, max_register_pages=2)
    prompt = list(range(26))                        # 6 full pages
    plan = pool.admit(0, prompt, "tag")
    assert plan.register == [4, 8]                  # capped at 2 boundaries
    assert pool.stats["register_capped"] == 1
    for b in plan.register:
        pool.register_prefix(0, prompt, "tag", b)
    assert len(pool.index) == 2
    plan2 = pool.admit(1, prompt, "tag")
    assert plan2.shared_tokens == 8                 # deepest registered page


def test_controller_driven_pool_reclaim():
    """pool_pages as the runtime's reclaimable knob: a QoS violation at the
    most-approximate variant RECLAIMs pool quanta (prefix cache evicted
    first, live requests untouched); slack RETURNs them before stepping
    toward precise — and a request served after the regrow matches the
    precise dense reference."""
    cfg, params = setup("gemma2-27b")
    table = serving_table(cfg, slots=4, max_len=64)
    monitor = LatencyMonitor(qos_target_s=1e-7, window=256, min_samples=4)
    runtime = PliantRuntime(table, monitor,
                            ControllerConfig(decision_interval_s=0.0))
    eng = ServeEngine(cfg, batch_slots=4, max_len=64, params=params,
                      runtime=runtime, paged=True, page_size=8)
    assert runtime.cfg.max_reclaim == eng.pool.max_quanta > 0
    reqs = [Request(i, prompt=[3 + i, 11, 7], max_new=10) for i in range(8)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    acts = [h["action"] for h in runtime.history]
    assert "set_most_approx" in acts and "reclaim_chips" in acts, acts
    assert eng.pool.stats["reclaim_events"] >= 1
    assert eng.pool.reclaimed > 0
    assert all(r.done and len(r.out) == 10 for r in reqs), \
        "reclaim must not corrupt live requests"

    monitor.qos_target_s = 1e9                  # slack: return pages, then
    guard = 0                                   # step back toward precise
    while (eng.active_variant != 0 or runtime.reclaimed > 0) and guard < 30:
        more = [Request(100 + guard * 10 + i, prompt=[2 + i, 5], max_new=8)
                for i in range(4)]
        for r in more:
            eng.submit(r)
        eng.run()
        guard += 1
    assert eng.active_variant == 0 and eng.pool.reclaimed == 0, \
        runtime.history
    assert "return_chips" in [h["action"] for h in runtime.history]

    late = Request(999, prompt=[9, 8, 7], max_new=6)
    eng.submit(late)
    eng.run()
    ref, _ = drive(cfg, params, [late.prompt], max_new=6, paged=False,
                   slots=1)
    assert late.out == ref[0]


def test_stall_free_admission_bounds_decoder_gaps():
    """A 32-chunk prompt admitted mid-run must not stall concurrent
    decoders: the engine runs AT MOST ONE admission chunk between decode
    executions (the stall-free budget), and every request's outputs still
    match the dense ring engine token-by-token."""
    cfg, params = setup("phi4-mini-3.8b")
    rng = np.random.default_rng(13)
    chunk = 2
    long_prompt = list(rng.integers(1, cfg.vocab_size, 32 * chunk))
    shorts = [list(rng.integers(1, cfg.vocab_size, 5)) for _ in range(2)]

    def run(paged):
        eng = ServeEngine(cfg, batch_slots=3, max_len=96, params=params,
                          prefill_chunk=chunk, paged=paged, page_size=4)
        events = []
        if paged:
            orig = eng._prefill_exe

            def counting(C):
                fn = orig(C)
                return lambda *a, **k: (events.append("chunk"), fn(*a, **k))[1]

            eng._prefill_exe = counting
            for vi, fn in list(eng._decodes.items()):
                eng._decodes[vi] = (
                    lambda f: lambda *a, **k:
                        (events.append("decode"), f(*a, **k))[1])(fn)
        reqs = [Request(i, prompt=list(p), max_new=50)
                for i, p in enumerate(shorts)]
        for r in reqs:
            eng.submit(r)
        for _ in range(6):
            eng.step()                  # shorts admitted and mid-decode
        big = Request(9, prompt=list(long_prompt), max_new=4)
        eng.submit(big)
        eng.run()
        assert all(r.done for r in reqs + [big])
        return [r.out for r in reqs + [big]], events

    dense, _ = run(paged=False)
    paged, events = run(paged=True)
    assert paged == dense, (paged, dense)
    # after the first decode, no two admission chunks back-to-back: a long
    # prompt costs active decoders at most one chunk per token
    tail = events[events.index("decode"):]
    assert "decode" in tail and "chunk" in tail
    for a, b in zip(tail, tail[1:]):
        assert not (a == "chunk" and b == "chunk"), tail


def test_window_pages_freed_keeps_occupancy_flat():
    """Banded-only arch on a long decode: pages that fall out of the
    attention window are freed at window-exit boundaries, so pool occupancy
    stays FLAT instead of growing with generation length — and freeing dead
    pages never changes outputs."""
    import dataclasses
    from repro.configs.base import LOCAL_ATTN
    base = get_config("gemma2-27b-smoke")
    cfg = dataclasses.replace(base, name="banded-smoke",
                              pattern=(LOCAL_ATTN,), n_layers=2, window=8)
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(17)
    prompt = list(rng.integers(1, cfg.vocab_size, 10))

    def run(window_free):
        eng = ServeEngine(cfg, batch_slots=1, max_len=128, params=params,
                          prefill_chunk=5, paged=True, page_size=4)
        assert eng._window_free == (cfg.window if window_free else 0) or \
            not window_free
        if not window_free:
            eng._window_free = 0
        req = Request(0, prompt=list(prompt), max_new=80)
        eng.submit(req)
        live = []
        while not req.done:
            eng.step()
            if eng.slots[0] is not None:
                live.append(eng.pool.live_slot_pages())
        return req.out, live, eng

    out_free, live, eng = run(window_free=True)
    out_keep, live_keep, _ = run(window_free=False)
    assert out_free == out_keep                   # freed pages were dead
    assert eng.pool.stats["window_freed"] > 0
    # steady state: window pages + the write page, NOT position/page_size
    steady = live[len(live) // 2:]
    bound = cfg.window // eng.page_size + 2
    assert max(steady) <= bound, (max(steady), bound)
    assert max(steady) - min(steady) <= 1         # flat
    assert max(live_keep) > bound                 # without freeing it grows
    # total pool usage = live pages + index-pinned prefix pages, also flat
    pinned = sum(len(e.pages) for e in eng.pool.index.values())
    assert eng.pool.used <= bound + pinned


def test_prefill_exe_cache_knob_keyed_and_bounded():
    """Admission executables are keyed by knobs (table entries with equal
    admission knobs share one compiled chunk cell), LRU-bounded, and evicted
    on variant retirement only when no live variant shares the knobs."""
    cfg, params = setup("phi4-mini-3.8b")
    int8 = ApproxKnobs(matmul_precision="int8")
    table = VariantTable([Variant(PRECISE, 1.0, 0.0),
                          Variant(int8, 0.8, 0.01),
                          Variant(int8, 0.7, 0.02)])   # same admission knobs
    eng = ServeEngine(cfg, batch_slots=2, max_len=32, params=params,
                      table=table)
    eng._prefill_exe(4)
    eng.set_variant(1)
    eng._prefill_exe(4)
    eng.set_variant(2)
    eng._prefill_exe(4)                         # shares variant 1's cell
    assert len(eng._prefills) == 2
    assert eng._prefill_exe(4) is eng._prefill_exe(4)

    eng.set_variant(0)
    eng.retire_variant(2)                       # variant 1 still uses int8
    assert any(k[0] == int8 for k in eng._prefills)
    eng.retire_variant(1)                       # last int8 user retired
    assert not any(k[0] == int8 for k in eng._prefills)
    assert 1 not in eng._decodes and 2 not in eng._decodes

    eng.max_prefill_exes = 2
    for c in (1, 2, 3, 5):
        eng._prefill_exe(c)
    assert len(eng._prefills) <= 2


def test_paged_engine_multi_device(subproc):
    """8-device mesh: slot-affinity layout — pool page dim AND block-table
    slot dim sharded over the same batch axes, kv_heads over "model" when
    divisible; outputs equal the single-device paged engine, prefix hits
    included."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.models.attention import PagedKVCache
from repro.serve.engine import Request, ServeEngine

cfg = get_config("phi4-mini-3.8b-smoke")
params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
rng = np.random.default_rng(1)
prefix = list(rng.integers(1, cfg.vocab_size, 8))
prompts = [prefix + list(rng.integers(1, cfg.vocab_size, 3))
           for _ in range(6)]

def run(mesh):
    eng = ServeEngine(cfg, batch_slots=4, max_len=32, params=params,
                      mesh=mesh, prefill_chunk=3, paged=True, page_size=8)
    reqs = [Request(i, prompt=list(p), max_new=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return eng, [r.out for r in reqs]

eng_ref, ref = run(None)
eng_sh, got = run(make_mesh((2, 4), ("data", "model")))
assert got == ref, (got, ref)
pg = [c for c in eng_sh.caches if isinstance(c, PagedKVCache)]
assert pg
for c in pg:
    # slot-affinity: pages split over the batch axes (device-local to their
    # slots' shard); smoke kv_heads don't divide the model axis -> replicated
    assert c.kp.sharding.spec == P(None, "data", None, None, None), \\
        c.kp.sharding
    assert c.block.sharding.spec == P(None, "data", None), c.block.sharding
# prefix namespaces are per-shard (pages must stay device-local): 6
# shared-prefix requests over 2 shards pay one cold miss per shard
assert eng_sh.pool.stats["prefix_hits"] >= 4
print("PAGED_DIST_OK")
""", devices=8)
    assert "PAGED_DIST_OK" in out
