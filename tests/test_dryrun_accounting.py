"""Validates the loop-calibrated cost accounting (flags.py) against a fully
unrolled compile on a small cell, and the HLO collective-byte parser."""
import pytest

from repro import roofline


def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %all-gather.1 = bf16[2048,1024]{1,0} all-gather(bf16[128,1024]{1,0} %p0)
  %all-reduce.7 = f32[4096]{0} all-reduce(f32[4096]{0} %p1), replica_groups={}
  %reduce-scatter.2 = f32[256]{0} reduce-scatter(f32[4096]{0} %p2)
  %all-to-all.9 = s8[64,128]{1,0} all-to-all(s8[64,128]{1,0} %p3)
  %collective-permute.3 = bf16[32,32]{1,0} collective-permute(bf16[32,32]{1,0} %p4)
  %add.1 = f32[10]{0} add(f32[10]{0} %a, f32[10]{0} %b)
"""
    got = roofline.collective_bytes(hlo)
    assert got["all-gather"] == 2048 * 1024 * 2           # result bytes
    assert got["all-reduce"] == 2 * 4096 * 4              # ring 2x
    assert got["reduce-scatter"] == 4096 * 4              # operand larger
    assert got["all-to-all"] == 64 * 128
    assert got["collective-permute"] == 32 * 32 * 2
    assert "add" not in got


def test_probe_calibration_matches_full_unroll(subproc):
    """base + sum(mult_i * delta_i) == fully-unrolled cost (within 2%)."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro import flags, roofline
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh
from repro.launch import dryrun
from repro.dist import annotate

cfg = get_config("mamba2-780m-smoke")   # has groups + ce + ssd loops
shape = ShapeConfig("t", 64, 8, "train")
mesh = make_mesh((2, 4), ("data", "model"))
annotate.set_batch_axes(("data",))
knobs = dryrun.resolve_variant("precise", cfg)

def measure():
    return dryrun._compile_and_measure(cfg, shape, mesh, knobs, policy="tp",
                                       n_micro=2, remat="full")

flags.reset_unroll()
base = measure()
mults = dryrun.loop_trips(cfg, shape, knobs, 2, "full")
flops = base["flops"]; byts = base["bytes_accessed"]
for site, extra in mults.items():
    flags.reset_unroll(); flags.set_unroll(site, 2)
    p = measure()
    flops += extra * max(p["flops"] - base["flops"], 0.0)
    byts += extra * max(p["bytes_accessed"] - base["bytes_accessed"], 0.0)
# ground truth: unroll every site fully
flags.reset_unroll()
from repro.approx.knobs import keep_groups
from repro.models.lm import ce_chunk
g = len(keep_groups(cfg.n_groups, 0.0))
flags.set_unroll("groups", g)
flags.set_unroll("ce", 64 // ce_chunk(64))
flags.set_unroll("ssd", 64 // cfg.ssm.chunk)
flags.set_unroll("micro", 2)
full = measure()
rel_f = abs(flops - full["flops"]) / full["flops"]
rel_b = abs(byts - full["bytes_accessed"]) / full["bytes_accessed"]
print(f"calibrated {flops:.4e} vs unrolled {full['flops']:.4e} rel {rel_f:.4f}")
print(f"bytes rel {rel_b:.4f}")
assert rel_f < 0.02, rel_f
assert rel_b < 0.05, rel_b
print("CALIBRATION_OK")
""", devices=8, timeout=420)
    assert "CALIBRATION_OK" in out
