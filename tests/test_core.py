"""Pliant core: controller state machine (Fig. 3), round-robin arbiter
fairness (§4.4), monitor, explorer Pareto properties — property-based where
the invariant is over a space (hypothesis)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.approx.knobs import ApproxKnobs, PRECISE, keep_groups
from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core.controller import (Action, ControllerConfig, PliantController,
                                   RoundRobinArbiter)
from repro.core.explorer import (analytic_quality_loss, explore, knob_grid,
                                 pareto_front)
from repro.core.monitor import LatencyMonitor


# ------------------------------------------------------------- controller --

def test_fig3_transitions():
    c = PliantController(n_variants=4,
                         cfg=ControllerConfig(max_reclaim=2))
    # violation from precise -> jump straight to most approximate
    assert c.tick(True, -0.5) == Action.SET_MOST_APPROX
    assert c.state.variant == 3
    # still violating -> reclaim chips one per tick
    assert c.tick(True, -0.2) == Action.RECLAIM_CHIPS
    assert c.tick(True, -0.2) == Action.RECLAIM_CHIPS
    assert c.state.reclaimed == 2
    assert c.tick(True, -0.2) == Action.HOLD          # reclaim cap
    # met with slack -> chips first, then variants, one per tick
    assert c.tick(False, 0.3) == Action.RETURN_CHIPS
    assert c.tick(False, 0.3) == Action.RETURN_CHIPS
    assert c.tick(False, 0.3) == Action.STEP_PRECISE
    assert c.state.variant == 2
    # met without slack -> hold
    assert c.tick(False, 0.05) == Action.HOLD
    # violation while mid-range -> jump to most approximate again
    assert c.tick(True, -0.1) == Action.SET_MOST_APPROX
    assert c.state.variant == 3


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.booleans(),
                          st.floats(-1, 1, allow_nan=False)),
                min_size=1, max_size=60),
       st.integers(2, 8), st.integers(1, 6))
def test_controller_invariants(ticks, n_variants, max_reclaim):
    """State always in bounds; violations never decrease approximation."""
    c = PliantController(n_variants,
                         ControllerConfig(max_reclaim=max_reclaim))
    for violated, slack in ticks:
        before = (c.state.variant, c.state.reclaimed)
        c.tick(violated, slack)
        assert 0 <= c.state.variant < n_variants
        assert 0 <= c.state.reclaimed <= max_reclaim
        if violated:
            assert c.state.variant >= before[0]
            assert c.state.reclaimed >= before[1]
        # at most one knob moves by at most one step (except the jump)
        dv = abs(c.state.variant - before[0])
        dr = abs(c.state.reclaimed - before[1])
        assert dr <= 1
        assert (dv == 0) or (dr == 0)


@settings(max_examples=100, deadline=None)
@given(st.integers(2, 5), st.integers(2, 4), st.integers(0, 3))
def test_round_robin_fairness(n_apps, n_variants, start):
    """Under sustained violation, no app is penalized disproportionately:
    max spread of (variant jumps, reclaimed chips) across apps <= 1 round."""
    arb = RoundRobinArbiter([n_variants] * n_apps,
                            ControllerConfig(max_reclaim=4), start=start)
    for _ in range(n_apps * 10):
        arb.tick(True, -0.5)
    reclaimed = [s.reclaimed for s in arb.states]
    assert max(reclaimed) - min(reclaimed) <= 1
    assert all(s.variant == n_variants - 1 for s in arb.states)


def test_round_robin_recovery_order():
    arb = RoundRobinArbiter([3, 3], ControllerConfig(max_reclaim=2), start=0)
    for _ in range(6):
        arb.tick(True, -0.5)
    # chips come back before variants step toward precise
    acts = [arb.tick(False, 0.5)[0] for _ in range(4)]
    assert acts[:2] == [Action.RETURN_CHIPS, Action.RETURN_CHIPS] or \
        Action.RETURN_CHIPS in acts[:2]
    assert all(a in (Action.RETURN_CHIPS, Action.STEP_PRECISE)
               for a in acts)


# ---------------------------------------------------------------- monitor --

def test_monitor_p99_accuracy():
    m = LatencyMonitor(qos_target_s=1.0, window=4096)
    rng = np.random.default_rng(0)
    lat = rng.lognormal(mean=0.0, sigma=0.3, size=4096)
    for x in lat:
        m.record(x)
    true_p99 = float(np.percentile(lat, 99))
    assert abs(m.p99() - true_p99) / true_p99 < 0.1
    assert m.qos_violated() == (m.p99() > 1.0)


def test_monitor_adaptive_rate():
    m = LatencyMonitor(qos_target_s=10.0, window=512)
    for x in np.full(512, 0.1):        # far below target
        m.record(x)
    low_rate = m.sample_rate
    m2 = LatencyMonitor(qos_target_s=10.0, window=512)
    for x in np.full(512, 9.9):        # at the boundary
        m2.record(x)
    assert m2.sample_rate == 1.0
    assert low_rate < 1.0


def test_monitor_slack_sign():
    m = LatencyMonitor(qos_target_s=1.0)
    for x in np.full(128, 2.0):
        m.record(x)
    assert m.slack() < 0
    m.reset_window()
    for x in np.full(128, 0.5):
        m.record(x)
    assert m.slack() > 0


# --------------------------------------------------------------- explorer --

@settings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 1, allow_nan=False),
                          st.floats(0.1, 2, allow_nan=False)),
                min_size=1, max_size=40))
def test_pareto_front_no_dominated(points):
    front = pareto_front(points)
    assert front, "front never empty"
    chosen = [points[i] for i in front]
    for q, t in chosen:
        assert not any((q2 <= q and t2 < t) or (q2 < q and t2 <= t)
                       for q2, t2 in points), "dominated point on front"
    # sorted by quality loss, time strictly decreasing along the front
    ts = [t for _, t in chosen]
    assert all(ts[i] > ts[i + 1] for i in range(len(ts) - 1))


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "olmoe-1b-7b",
                                  "mamba2-780m"])
def test_explore_variant_table(arch):
    cfg = get_config(arch)
    table = explore(cfg, SHAPES["train_4k"], max_loss=0.05)
    assert table.variants[0].knobs.is_precise()
    assert all(v.quality_loss <= 0.05 for v in table.variants)
    losses = [v.quality_loss for v in table.variants]
    assert losses == sorted(losses)
    times = [v.rel_time for v in table.variants]
    assert all(times[i] >= times[i + 1] for i in range(len(times) - 1))
    if cfg.moe is not None:
        # with a collective-bound baseline (MoE all-to-all dominant, as the
        # dry-run artifacts show) the top-k knob must reach the frontier
        art = {"compute_s": 1.0, "memory_s": 0.8, "collective_s": 1.6}
        t2 = explore(cfg, SHAPES["train_4k"], max_loss=0.05,
                     baseline_art=art)
        assert any(v.knobs.topk_override for v in t2.variants[1:]), \
            "MoE arch should expose the top-k knob on its frontier"


def test_knob_grid_family_aware():
    ssm = get_config("mamba2-780m")
    assert all(k.kv_keep_stride == 1 for k in knob_grid(ssm)), \
        "attention-free arch must not get attention knobs"
    dense = get_config("phi4-mini-3.8b")
    assert all(k.topk_override == 0 for k in knob_grid(dense))
    serving = knob_grid(dense, serving=True)
    assert all(k.token_drop == 0 and k.sync_period == 1 for k in serving)


def test_keep_groups_static():
    assert keep_groups(8, 0.0) == tuple(range(8))
    kept = keep_groups(8, 0.25)
    assert len(kept) == 6 and kept[0] == 0 and kept[-1] == 7
    assert keep_groups(8, 0.9) [0] == 0     # always >= 2 groups
    assert len(keep_groups(8, 0.9)) >= 2


def test_quality_model_monotone():
    cfg = get_config("phi4-mini-3.8b")
    assert analytic_quality_loss(cfg, PRECISE) == 0.0
    a = analytic_quality_loss(cfg, ApproxKnobs(token_drop=0.25))
    b = analytic_quality_loss(cfg, ApproxKnobs(token_drop=0.5))
    assert 0 < a < b


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 64), st.floats(0, 0.95, allow_nan=False))
def test_keep_groups_properties(n_groups, skip):
    kept = keep_groups(n_groups, skip)
    assert len(kept) >= min(2, n_groups)
    assert kept == tuple(sorted(set(kept)))
    assert kept[0] == 0 and kept[-1] == n_groups - 1 or n_groups == 1
    assert all(0 <= i < n_groups for i in kept)


def test_knobs_describe_roundtrip_distinct():
    from repro.core.explorer import knob_grid
    cfg = get_config("olmoe-1b-7b")
    names = [k.describe() for k in knob_grid(cfg)]
    assert len(names) == len(set(names)), "variant names must be unique"
    assert "precise" in names
