import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


def run_py(code: str, *, devices: int = 1, timeout: int = 420) -> str:
    """Run a python snippet in a fresh process (own XLA device count)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    if devices > 1:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_py
