"""Distribution correctness on an 8-device host mesh (subprocess so the main
pytest process keeps 1 device): sharded step == single-device step, EP MoE ==
local MoE, compressed collectives, pod param sync, elastic reshard restore."""
import pytest


def test_sharded_train_step_matches_single_device(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.dist import sharding, annotate
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.train import optim, step as step_mod

cfg = get_config("phi4-mini-3.8b-smoke")
params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
opt = optim.init_opt(params)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0,
                                      cfg.vocab_size)}
step = step_mod.make_train_step(cfg, remat="none")
p_ref, _, m_ref = jax.jit(step)(params, opt, batch)

mesh = make_mesh((2, 4), ("data", "model"))
annotate.set_batch_axes(("data",))
psh = sharding.param_shardings(cfg, mesh, "tp")
params_s = jax.device_put(params, psh)
opt_s = optim.OptState(step=jax.device_put(opt.step),
                       m=jax.device_put(opt.m, psh),
                       v=jax.device_put(opt.v, psh))
with jax.set_mesh(mesh):
    p_sh, _, m_sh = jax.jit(step, in_shardings=(psh, None, None),
                            out_shardings=(psh, None, None))(
        params_s, opt_s, batch)
print("LOSS", float(m_ref["loss"]), float(m_sh["loss"]))
np.testing.assert_allclose(float(m_ref["loss"]), float(m_sh["loss"]),
                           rtol=1e-4)
for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-4)
print("SHARDED_STEP_OK")
""", devices=8)
    assert "SHARDED_STEP_OK" in out


def test_moe_ep_matches_local(subproc):
    out = subproc("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.launch.mesh import make_mesh
from repro.models import moe as moe_mod
from repro.models.common import init_params

cfg = get_config("olmoe-1b-7b-smoke")
# high capacity so EP reordering cannot change the capacity-drop set
cfg = dataclasses.replace(cfg, moe=MoEConfig(n_experts=8, top_k=2,
                                             capacity_factor=8.0))
params = init_params(moe_mod.moe_specs(cfg), jax.random.PRNGKey(0),
                     jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                      jnp.float32)
y_local, aux_local = moe_mod.moe(params, x, cfg)
mesh = make_mesh((2, 4), ("data", "model"))
with jax.set_mesh(mesh):
    y_ep, aux_ep = jax.jit(lambda p, x: moe_mod.moe(
        p, x, cfg, ep_axis="model", mesh=mesh))(params, x)
np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_ep),
                           rtol=2e-4, atol=2e-5)
# aux is a per-shard load-balance statistic (standard practice): only
# finiteness is required, not equality with the global statistic
assert np.isfinite(float(aux_ep))
print("MOE_EP_OK")
""", devices=8)
    assert "MOE_EP_OK" in out


def test_compressed_pmean_and_pod_sync(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist.collectives import compressed_pmean, pod_sync_params
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (2, 64)),
        "b": jax.random.normal(jax.random.PRNGKey(1), (32,))}
# per-pod different values: shard leading dim over pod inside shard_map
def body(t):
    return compressed_pmean(t, "pod")
with jax.set_mesh(mesh):
    f = jax.shard_map(body, mesh=mesh,
                      in_specs=({"w": P("pod", None), "b": P(None)},),
                      out_specs={"w": P("pod", None), "b": P(None)},
                      axis_names={"pod"}, check_vma=False)
    got = jax.jit(f)(tree)
want_w = jnp.mean(tree["w"], axis=0, keepdims=True)
# both pod-shards now hold the mean; int8 wire -> ~1% tolerance
np.testing.assert_allclose(np.asarray(got["w"][0]), np.asarray(want_w[0]),
                           rtol=0.05, atol=0.02)
np.testing.assert_allclose(np.asarray(got["w"][1]), np.asarray(want_w[0]),
                           rtol=0.05, atol=0.02)

# pod_sync_params: replicated params stay fixed under sync (mean of equals)
params = {"w": jax.random.normal(jax.random.PRNGKey(2), (8, 8))}
with jax.set_mesh(mesh):
    synced = jax.jit(lambda p: pod_sync_params(p, mesh))(params)
np.testing.assert_allclose(np.asarray(synced["w"]), np.asarray(params["w"]),
                           rtol=1e-6)
print("COLLECTIVES_OK")
""", devices=8)
    assert "COLLECTIVES_OK" in out


def test_elastic_reshard_restore(subproc):
    """Fault tolerance at scale: save on a (2,4) mesh, restore onto (4,2)
    and (1,8) meshes — elastic scaling across topologies."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro.ckpt import checkpoint as ck
from repro.configs import get_config
from repro.dist import sharding
from repro.launch.mesh import make_mesh
from repro.models import api

cfg = get_config("gemma2-27b-smoke")
params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
mesh1 = make_mesh((2, 4), ("data", "model"))
p1 = jax.device_put(params, sharding.param_shardings(cfg, mesh1, "fsdp_tp"))
d = tempfile.mkdtemp()
ck.save(d + "/step_1", p1, 1)
for shape in [(4, 2), (1, 8)]:
    mesh2 = make_mesh(shape, ("data", "model"))
    sh2 = sharding.param_shardings(cfg, mesh2, "tp")
    restored, step = ck.restore(d + "/step_1", jax.eval_shape(lambda: params),
                                shardings=sh2)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("ELASTIC_OK")
""", devices=8)
    assert "ELASTIC_OK" in out


def test_seq_sharded_decode_cache(subproc):
    """Decode with the KV cache sequence-sharded over the model axis equals
    unsharded decode (GSPMD partial-softmax reductions)."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.dist import sharding
from repro.launch.mesh import make_mesh
from repro.models import api, lm

cfg = get_config("phi4-mini-3.8b-smoke")
params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
B, S = 4, 32
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
caches = lm.init_caches(cfg, B, S, dtype=jnp.float32)
step = lambda p, t, pos, c: lm.decode_step(p, t, pos, c, cfg)
ref_logits, ref_caches = None, caches
for i in range(4):
    ref_logits, ref_caches = jax.jit(step)(params, toks[:, i:i+1],
                                           jnp.full((B,), i, jnp.int32),
                                           ref_caches)
mesh = make_mesh((2, 4), ("data", "model"))
from repro.configs.base import SHAPES, ShapeConfig
shp = ShapeConfig("t", S, B, "decode")
cache_sh, _ = sharding.cache_shardings(cfg, shp, mesh)
psh = sharding.param_shardings(cfg, mesh, "tp")
with jax.set_mesh(mesh):
    params_s = jax.device_put(params, psh)
    caches_s = jax.device_put(lm.init_caches(cfg, B, S, dtype=jnp.float32),
                              cache_sh)
    jstep = jax.jit(step, in_shardings=(psh, None, None, cache_sh),
                    out_shardings=(None, cache_sh))
    logits = None
    for i in range(4):
        logits, caches_s = jstep(params_s, toks[:, i:i+1],
                                 jnp.full((B,), i, jnp.int32), caches_s)
np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                           rtol=2e-3, atol=2e-3)
print("SEQ_SHARDED_DECODE_OK")
""", devices=8)
    assert "SEQ_SHARDED_DECODE_OK" in out
