"""Knob -> collective threading: the train step applies the compressed pod
reduction when ``grad_compress`` calls for it, elides per-step pod sync under
``sync_period`` (the launcher syncs instead), and the periodic sync is exact
on replicated params."""
import jax
import pytest

from repro.approx.knobs import ApproxKnobs, PRECISE
from repro.train import step as step_mod


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_grad_reduce_selection():
    pod = _FakeMesh({"pod": 2, "data": 4})
    podless = _FakeMesh({"data": 2, "model": 4})
    tp_only = _FakeMesh({"model": 4})
    assert step_mod.grad_reduce_for(PRECISE, None) is None
    assert step_mod.grad_reduce_for(PRECISE, tp_only) is None
    # any data/pod mesh gets the owned in-pod region; the pod wire and its
    # compression are per-knob facts exposed on the callable
    r = step_mod.grad_reduce_for(PRECISE, pod)
    assert r is not None and r.pod_wire and not r.compress
    r = step_mod.grad_reduce_for(ApproxKnobs(grad_compress="int8"), podless)
    assert r is not None and not r.pod_wire and r.compress
    r = step_mod.grad_reduce_for(ApproxKnobs(grad_compress="int8"), pod)
    assert r.pod_wire and r.compress
    # sync elision: the pod collective is dropped from the region at trace
    # time, launcher syncs instead; the in-pod pmean region remains
    r = step_mod.grad_reduce_for(
        ApproxKnobs(grad_compress="int8", sync_period=4), pod)
    assert r is not None and not r.pod_wire


def test_pod_sync_noop_without_pod_axis():
    params = {"w": jax.numpy.ones((4, 4))}
    assert step_mod.pod_sync(params, None) is params


def test_compressed_grad_step_matches_precise(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.approx.knobs import ApproxKnobs
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.train import optim, step as step_mod

cfg = get_config("phi4-mini-3.8b-smoke")
params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
opt = optim.init_opt(params)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0,
                                      cfg.vocab_size)}
p_ref, _, m_ref = jax.jit(step_mod.make_train_step(cfg, remat="none"))(
    params, opt, batch)

mesh = make_mesh((2, 4), ("pod", "data"))
knobs = ApproxKnobs(grad_compress="int8")
step = step_mod.make_train_step(cfg, knobs, remat="none", mesh=mesh)
with jax.set_mesh(mesh):
    p_c, _, m_c = jax.jit(step)(params, opt, batch)
# loss is computed before the reduction: identical
np.testing.assert_allclose(float(m_ref["loss"]), float(m_c["loss"]),
                           rtol=1e-5)
# grads are pod-identical, so the int8-wire mean only adds quantization
# noise bounded by the wire format
for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_c)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=0.02, atol=1e-4)

# sync_period knob: launcher-side periodic sync is exact on replicated
# params (always full-precision wire — never re-rounds model state), and the
# jitted sync executable is cached across calls
synced = step_mod.pod_sync(p_c, mesh)
for a, b in zip(jax.tree.leaves(p_c), jax.tree.leaves(synced)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
synced2 = step_mod.pod_sync(synced, mesh)
assert len(step_mod._POD_SYNC_CACHE) == 1

# trace-time elision: under sync_period>1 the gradient-sync region carries
# NO pod collective in its jaxpr (only the in-pod data pmean); under
# sync_period==1 the pod wire is traced into the same region
grads = jax.tree.map(jnp.zeros_like, params)
r1 = step_mod.grad_reduce_for(knobs, mesh)
r4 = step_mod.grad_reduce_for(
    ApproxKnobs(grad_compress="int8", sync_period=4), mesh)
j1, j4 = str(jax.make_jaxpr(r1)(grads)), str(jax.make_jaxpr(r4)(grads))
assert "('pod',)" in j1 and "('data',)" in j1
assert "('pod',)" not in j4 and "('data',)" in j4
print("GRAD_COMPRESS_OK")
""", devices=8)
    assert "GRAD_COMPRESS_OK" in out
