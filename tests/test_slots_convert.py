"""Property tests for ``serve.slots.convert_caches`` (kv_quant hot-swap
re-encoding): int8 -> fp32 -> int8 round-trips must be idempotent, and
positions / cursors / block tables / Mamba state must be bit-identical
across any conversion chain — for both the dense ring and paged pool cache
layouts. Hypothesis-driven when available (tests/_hypothesis_compat.py
self-skips in sealed images); a fixed-seed smoke always runs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.configs import get_config
from repro.models import lm
from repro.models.attention import KVCache, PagedKVCache
from repro.models.mamba2 import MambaCache
from repro.serve import slots as slots_mod

ARCHS = ["phi4-mini-3.8b", "zamba2-2.7b", "mamba2-780m"]


def _random_fill(caches, seed):
    """Fill zero-initialized caches with random payloads: K/V values, valid
    position prefixes, nonzero cursors/block tables, random SSM state."""
    rng = np.random.default_rng(seed)

    def fill_kv(x):
        return jnp.asarray(rng.standard_normal(x.shape), x.dtype)

    out = []
    for c in caches:
        if isinstance(c, KVCache):
            W = c.pos.shape[2]
            n = int(rng.integers(0, W + 1))
            pos = np.full(c.pos.shape, -1, np.int32)
            pos[:, :, :n] = rng.integers(0, 64, (pos.shape[0],
                                                 pos.shape[1], n))
            out.append(KVCache(fill_kv(c.k), fill_kv(c.v), jnp.asarray(pos),
                               jnp.asarray(rng.integers(0, W, c.cursor.shape),
                                           jnp.int32)))
        elif isinstance(c, PagedKVCache):
            ppos = rng.integers(-1, 32, c.ppos.shape).astype(np.int32)
            block = rng.integers(0, c.kp.shape[1],
                                 c.block.shape).astype(np.int32)
            out.append(PagedKVCache(fill_kv(c.kp), fill_kv(c.vp),
                                    jnp.asarray(ppos), jnp.asarray(block)))
        else:
            assert isinstance(c, MambaCache), type(c)
            out.append(MambaCache(*(fill_kv(x) for x in c)))
    return tuple(out)


def _leaves_equal(a, b):
    return all(x.dtype == y.dtype and bool(jnp.all(x == y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _check_roundtrip(arch, seed, paged, batch=2, max_len=8):
    cfg = get_config(arch + "-smoke")
    if paged:
        caches = lm.init_paged_caches(cfg, batch, n_pages=8, page_size=4,
                                      max_pages=2, dtype=jnp.float32)
    else:
        caches = lm.init_caches(cfg, batch, max_len, dtype=jnp.float32)
    c0 = _random_fill(caches, seed)
    q1 = slots_mod.convert_caches(c0, True)          # fp32 -> int8
    dq = slots_mod.convert_caches(q1, False)         # int8 -> fp32
    q2 = slots_mod.convert_caches(dq, True)          # fp32 -> int8 again

    def kv_leaves(cs):
        return [(c.k, c.v) if isinstance(c, KVCache) else (c.kp, c.vp)
                for c in cs if isinstance(c, (KVCache, PagedKVCache))]

    # int8 -> fp32 -> int8 is idempotent: requantizing a dequantized ring
    # reproduces it bit-for-bit (values sit exactly on the KV_SCALE grid)
    for (k1, v1), (k2, v2) in zip(kv_leaves(q1), kv_leaves(q2)):
        assert k1.dtype == k2.dtype == jnp.int8
        assert bool(jnp.all(k1 == k2)) and bool(jnp.all(v1 == v2))
    # converting an already-matching tree is the identity
    assert _leaves_equal(q2, slots_mod.convert_caches(q2, True))
    assert _leaves_equal(c0, slots_mod.convert_caches(c0, False))

    # positions / cursors / block tables / Mamba state ride through every
    # conversion bit-identically
    def carried(cs):
        out = []
        for c in cs:
            if isinstance(c, KVCache):
                out += [c.pos, c.cursor]
            elif isinstance(c, PagedKVCache):
                out += [c.ppos, c.block]
            else:
                out += list(c)
        return out

    for chain in (q1, dq, q2):
        assert _leaves_equal(carried(c0), carried(chain))


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=10, deadline=None)
@given(arch=st.sampled_from(ARCHS), seed=st.integers(0, 2**16),
       paged=st.booleans(), batch=st.integers(1, 3))
def test_convert_roundtrip_property(arch, seed, paged, batch):
    _check_roundtrip(arch, seed, paged, batch=batch)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("paged", [False, True])
def test_convert_roundtrip_smoke(arch, paged):
    """Fixed-seed coverage for sealed images (no hypothesis)."""
    _check_roundtrip(arch, seed=0, paged=paged)
