"""Substrate: optimizer, data pipeline (determinism/sharding properties),
checkpoint roundtrip + async + retention + resume."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.ckpt import checkpoint as ck
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.train import optim


# ---------------------------------------------------------------- optimizer

def test_adamw_converges_on_quadratic():
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    opt = optim.init_opt(params)
    cfg = optim.OptConfig(lr=0.1, warmup=5, total_steps=200,
                          weight_decay=0.0)
    loss_fn = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(200):
        g = jax.grad(loss_fn)(params)
        params, opt, m = optim.adamw_update(g, opt, params, cfg)
    assert loss_fn(params) < 1e-2


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    opt = optim.init_opt(params)
    cfg = optim.OptConfig(lr=1e-3, clip_norm=1.0, warmup=0, total_steps=10)
    g = {"w": jnp.full(4, 1e6)}
    _, _, m = optim.adamw_update(g, opt, params, cfg)
    assert m["grad_norm"] > 1e5            # reported raw norm


def test_lr_schedule_shape():
    cfg = optim.OptConfig(lr=1.0, warmup=10, total_steps=110)
    lrs = [float(optim.lr_at(cfg, s)) for s in range(110)]
    assert lrs[0] < lrs[9]                  # warmup rises
    assert abs(lrs[10] - 1.0) < 0.02        # peak
    assert lrs[-1] < 0.02                   # cosine decays to ~0


# --------------------------------------------------------------------- data

def test_data_deterministic():
    cfg = DataConfig(vocab_size=101, seq_len=32, global_batch=4, seed=7)
    a = SyntheticLM(cfg).batch(3)
    b = SyntheticLM(cfg).batch(3)
    np.testing.assert_array_equal(a, b)
    c = SyntheticLM(cfg).batch(4)
    assert not np.array_equal(a, c)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4).map(lambda k: 2 ** k), st.integers(0, 5))
def test_data_host_shards_partition_global_batch(n_hosts, step):
    cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=8 * n_hosts,
                     seed=3)
    full = np.concatenate(
        [SyntheticLM(cfg, host_id=h, n_hosts=n_hosts).batch(step)
         for h in range(n_hosts)])
    ref = SyntheticLM(cfg, host_id=0, n_hosts=1).batch(step)
    np.testing.assert_array_equal(full, ref)   # shards tile the global batch


def test_data_in_vocab_and_learnable():
    cfg = DataConfig(vocab_size=53, seq_len=64, global_batch=8, seed=0)
    b = SyntheticLM(cfg).batch(0)
    assert b.min() >= 0 and b.max() < 53
    # copy motif present: position t % 16 == 0 repeats t-8 for t >= 8
    hits = np.mean([b[i, t] == b[i, t - 8]
                    for i in range(8) for t in range(16, 65, 16)])
    assert hits == 1.0


def test_prefetcher_orders_steps():
    cfg = DataConfig(vocab_size=31, seq_len=8, global_batch=2, seed=1)
    src = SyntheticLM(cfg)
    pf = Prefetcher(lambda s: src.batch(s), start_step=5)
    steps = [next(pf)[0] for _ in range(4)]
    pf.close()
    assert steps == [5, 6, 7, 8]


# --------------------------------------------------------------- checkpoint

def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": (jnp.ones((3,)), jnp.zeros((2, 2)))}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ck.save(tmp_path / "step_5", t, 5)
    restored, step = ck.restore(tmp_path / "step_5", jax.eval_shape(lambda: t))
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_manager_async_retention_resume(tmp_path):
    mgr = ck.CheckpointManager(tmp_path, period=2, keep=2)
    t = _tree()
    for step in range(1, 9):
        t = jax.tree.map(lambda x: x + 1 if x.dtype != jnp.int32 else x, t)
        mgr.maybe_save(t, step)
    mgr.wait()
    assert ck.latest_step(tmp_path) == 8
    kept = sorted(int(p.name.split("_")[-1])
                  for p in pathlib.Path(tmp_path).glob("step_*"))
    assert len(kept) <= 2
    restored, step = mgr.restore_latest(jax.eval_shape(lambda: t))
    assert step == 8
    np.testing.assert_allclose(np.asarray(restored["a"]), np.asarray(t["a"]))


def test_checkpoint_atomicity_overwrite(tmp_path):
    t = _tree(0)
    ck.save(tmp_path / "step_1", t, 1)
    t2 = jax.tree.map(lambda x: x * 2, t)
    ck.save(tmp_path / "step_1", t2, 1)     # overwrite is atomic
    restored, _ = ck.restore(tmp_path / "step_1", jax.eval_shape(lambda: t))
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(t2["a"]))


def test_train_resume_continues(tmp_path):
    """checkpoint/restart: resumed run continues from the saved step."""
    from repro.launch import train as train_mod
    loss1 = train_mod.main(["--arch", "mamba2-780m-smoke", "--steps", "16",
                            "--batch", "4", "--seq", "32",
                            "--ckpt-dir", str(tmp_path), "--ckpt-period",
                            "8"])
    loss2 = train_mod.main(["--arch", "mamba2-780m-smoke", "--steps", "24",
                            "--batch", "4", "--seq", "32",
                            "--ckpt-dir", str(tmp_path), "--resume"])
    assert np.isfinite(loss1) and np.isfinite(loss2)
    assert ck.latest_step(tmp_path) == 24
