"""Per-arch REDUCED-config smoke tests (required deliverable): instantiate
each family at toy scale, run one forward/train step on CPU, assert output
shapes and no NaNs; plus decode-vs-full equivalence per cache type."""
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx.knobs import ApproxKnobs, PRECISE
from repro.configs import ARCHS, get_config
from repro.configs.base import MoEConfig
from repro.models import api, encdec, lm
from repro.train import optim, step as step_mod

ALL = list(ARCHS)


def _batch(cfg, B=2, S=32, key=1):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(key),
                                          (B, S + 1), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_prefix_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", ALL)
def test_forward_and_train_step(name):
    cfg = get_config(name + "-smoke")
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = optim.init_opt(params)
    batch = _batch(cfg)
    step = jax.jit(step_mod.make_train_step(
        cfg, opt_cfg=optim.OptConfig(lr=1e-3, warmup=2, total_steps=10),
        remat="none"))
    params2, opt2, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"]), name
    assert jnp.isfinite(metrics["grad_norm"]) and metrics["grad_norm"] > 0
    # shapes preserved, params actually moved
    moved = 0.0
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
        assert a.shape == b.shape and a.dtype == b.dtype
        moved += float(jnp.sum(jnp.abs(a - b)))
    assert moved > 0
    assert int(opt2.step) == 1


@pytest.mark.parametrize("name", ["gemma3-12b", "zamba2-2.7b",
                                  "moonshot-v1-16b-a3b"])
def test_approx_variant_step(name):
    cfg = get_config(name + "-smoke")
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = optim.init_opt(params)
    knobs = ApproxKnobs(matmul_precision="int8", token_drop=0.5,
                        layer_skip=0.5,
                        topk_override=1 if cfg.moe else 0)
    step = jax.jit(step_mod.make_train_step(cfg, knobs, remat="none"))
    _, _, metrics = step(params, opt, _batch(cfg))
    assert jnp.isfinite(metrics["loss"])


@pytest.mark.parametrize("name", ["gemma3-12b", "whisper-large-v3",
                                  "zamba2-2.7b"])
def test_decode_matches_full_forward(name):
    cfg = get_config(name + "-smoke")
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.encoder_seq, cfg.d_model))
        enc = encdec.encode(params, frames, cfg, remat="none")
        h = encdec.decode_hidden(params, toks, enc, cfg, remat="none")
        want = lm.logits_fn(params, h[:, -1], cfg)
        caches = encdec.init_caches(cfg, B, S, dtype=jnp.float32)
        for i in range(S):
            got, caches = encdec.encdec_decode_step(
                params, toks[:, i:i+1], jnp.full((B,), i, jnp.int32),
                caches, enc, cfg)
    else:
        h, _ = lm.forward_hidden(params, toks, cfg, remat="none")
        want = lm.logits_fn(params, h[:, -1], cfg)
        caches = lm.init_caches(cfg, B, S, dtype=jnp.float32)
        step = jax.jit(lambda p, t, pos, c: lm.decode_step(p, t, pos, c, cfg))
        for i in range(S):
            got, caches = step(params, toks[:, i:i+1],
                               jnp.full((B,), i, jnp.int32), caches)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-3, atol=3e-3)


def test_microbatch_equals_full_batch_grads():
    cfg = get_config("phi4-mini-3.8b-smoke")
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = optim.init_opt(params)
    batch = _batch(cfg, B=4)
    s1 = jax.jit(step_mod.make_train_step(cfg, remat="none", n_micro=1))
    s2 = jax.jit(step_mod.make_train_step(cfg, remat="none", n_micro=2))
    p1, _, m1 = s1(params, opt, batch)
    p2, _, m2 = s2(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_remat_policies_equal_loss():
    cfg = get_config("mistral-large-123b-smoke")   # 2-level factorable groups
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    batch = _batch(cfg)
    lf = api.loss_fn(cfg)
    vals = []
    for remat in ["none", "full", "2level"]:
        loss, _ = jax.jit(lambda p, b, r=remat: lf(p, b, knobs=PRECISE,
                                                   remat=r))(params, batch)
        vals.append(float(loss))
    np.testing.assert_allclose(vals[0], vals[1], rtol=1e-5)
    np.testing.assert_allclose(vals[0], vals[2], rtol=1e-5)


def test_moe_capacity_drops_tokens_but_stays_finite():
    cfg = get_config("olmoe-1b-7b-smoke")
    cfg = dataclasses.replace(
        cfg, moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=0.25))
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    lf = api.loss_fn(cfg)
    loss, _ = jax.jit(lambda p, b: lf(p, b, knobs=PRECISE, remat="none"))(
        params, _batch(cfg))
    assert jnp.isfinite(loss)
