"""Megastep decode on an 8-simulated-device mesh (subprocess, like
test_sharded_decode): the fused K-token dispatch must be token-identical to
the single-device per-step engine across all four cache families, and the
donation + async-pipeline machinery must survive an elastic revoke/restore
mid-run with zero dropped requests — the drain point (`_drain_pipeline`)
flushes the in-flight megastep before cache surgery, and the re-homed
executables re-donate."""

ARCHS = ["phi4-mini-3.8b-smoke",   # MHA
         "gemma2-27b-smoke",       # GQA + local attention
         "zamba2-2.7b-smoke",      # hybrid attn/SSM
         "mamba2-780m-smoke"]      # pure SSM


def test_sharded_megastep_token_parity(subproc):
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.serve.engine import Request, ServeEngine

def drive(eng, cfg, n_req=6, prompt_len=10, max_new=5, shared=4):
    rng = np.random.default_rng(0)
    base = list(rng.integers(1, cfg.vocab_size, shared))
    reqs = [Request(i, prompt=base + list(
                rng.integers(1, cfg.vocab_size, prompt_len - shared)),
                    max_new=max_new) for i in range(n_req)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [list(r.out) for r in reqs]

mesh = make_mesh((2, 4), ("data", "model"))
for arch in %r:
    cfg = get_config(arch)
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng_m = ServeEngine(cfg, batch_slots=8, max_len=32, params=params,
                        mesh=mesh, paged=True, page_size=4,
                        use_kernel=True, kernel_interpret=True,
                        megastep_k=4)
    assert "megastep scan" in eng_m.explain_dispatch()
    out_m = drive(eng_m, cfg)
    assert eng_m.row_dispatches / max(eng_m.row_tokens, 1) <= 1.0
    eng_1 = ServeEngine(cfg, batch_slots=8, max_len=32, params=params,
                        paged=True, page_size=4, use_kernel=True,
                        kernel_interpret=True)
    out_1 = drive(eng_1, cfg)
    assert out_m == out_1, (arch, out_m, out_1)
    assert all(len(t) == 5 for t in out_m), out_m
    eng_m.pool.assert_consistent()
    print("MEGA_PARITY_OK", arch)
print("ALL_OK")
""" % ARCHS, devices=8)
    assert "ALL_OK" in out
    for arch in ARCHS:
        assert f"MEGA_PARITY_OK {arch}" in out


def test_megastep_donation_survives_revoke_restore(subproc):
    """Chaos interleaving: revoke 2 of 8 devices mid-run (grace deadline)
    and restore them later while the engine runs DONATED megasteps through
    the async double-buffered pipeline. The re-home must drain the
    in-flight megastep, migrate pages, rebuild (and re-donate) the
    executables, and complete every request token-identical to the
    unfaulted megastep run — zero drops."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.dist import elastic
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.serve.engine import Request, ServeEngine

cfg = get_config("phi4-mini-3.8b-smoke")
params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
rng = np.random.default_rng(11)
prompts = [list(rng.integers(1, cfg.vocab_size, 7)) for _ in range(8)]

def run(script):
    mesh = make_mesh((4, 2), ("data", "model"))
    eng = ServeEngine(cfg, batch_slots=4, max_len=32, params=params,
                      mesh=mesh, paged=True, page_size=4, prefill_chunk=3,
                      megastep_k=4)
    assert eng.donate
    reqs = [Request(i, prompt=list(p), max_new=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    inj = elastic.FaultInjector.parse(script) if script else None
    steps = 0
    while not eng.idle and steps < 2000:
        if inj is not None:
            for ev in inj.due(steps):
                eng.inject(ev)
        eng.step()
        steps += 1
    assert eng.idle, "drained"
    return eng, reqs

ref_eng, ref = run("")
eng, got = run("revoke@4+2:2,restore@9")
assert all(r.done for r in got), [r.uid for r in got if not r.done]
assert not eng.rejected, "zero dropped requests"
assert [r.out for r in got] == [r.out for r in ref], "token parity"
assert eng.stats["rehomes"] == 2
# the in-flight megastep was flushed, not leaked, across both re-homes
assert eng._inflight is None and eng._carry is None
print("MEGA_CHAOS_OK")
""", devices=8)
    assert "MEGA_CHAOS_OK" in out
