"""Chunked-prefill admission and Pliant-controlled serving.

Engine-level equivalence: admission via ``prefill_chunk`` + slot scatter must
reproduce the seed token-by-token warmup outputs EXACTLY (greedy) for the
attention, hybrid, and Mamba cache families. Control: a forced QoS violation
must make ``PliantRuntime`` hot-swap the serving variant mid-run — crossing
the ``kv_quant`` boundary both ways — with decode continuing across the swap.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.controller import ControllerConfig
from repro.core.monitor import LatencyMonitor
from repro.core.runtime import PliantRuntime
from repro.launch.serve import serving_table
from repro.models import api, lm
from repro.models.attention import KVCache
from repro.serve.engine import Request, ServeEngine

_PARAMS = {}


def setup(name):
    cfg = get_config(name + "-smoke")
    if name not in _PARAMS:
        _PARAMS[name] = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, _PARAMS[name]


def greedy_warmup_ref(cfg, params, prompt, n, max_len=64, knobs=None):
    """The seed engine's admission: prompt fed through decode steps."""
    from repro.approx.knobs import PRECISE
    kn = knobs or PRECISE
    caches = lm.init_caches(cfg, 1, max_len, dtype=jnp.float32,
                            quantized=kn.kv_quant)
    step = jax.jit(lambda p, t, po, c: lm.decode_step(p, t, po, c, cfg, kn))
    out, cursor, cur, pos = [], 0, prompt[0], 0
    while len(out) < n:
        logits, caches = step(params, jnp.asarray([[cur]]),
                              jnp.asarray([pos]), caches)
        pos += 1
        if cursor + 1 < len(prompt):
            cursor += 1
            cur = prompt[cursor]
            continue
        cur = int(jnp.argmax(logits[0]))
        out.append(cur)
    return out


@pytest.mark.parametrize("name", ["phi4-mini-3.8b",     # attention
                                  "zamba2-2.7b",        # hybrid (+shared)
                                  "mamba2-780m",        # pure SSM
                                  "gemma2-27b"])        # local+global attn
def test_admission_matches_tokenwise_warmup(name):
    cfg, params = setup(name)
    rng = np.random.default_rng(3)
    # prompt (7) > prefill_chunk (3): exercises multi-chunk admission with a
    # ragged tail; 4 requests through 2 slots: staggered ring offsets
    eng = ServeEngine(cfg, batch_slots=2, max_len=64, params=params,
                      prefill_chunk=3)
    reqs = [Request(uid, prompt=list(rng.integers(1, cfg.vocab_size, 7)),
                    max_new=5) for uid in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        want = greedy_warmup_ref(cfg, params, r.prompt, 5)
        assert r.done and r.out == want, (r.uid, r.out, want)


def test_admission_chunk_size_invariance():
    """Outputs must not depend on the admission chunk size."""
    cfg, params = setup("phi4-mini-3.8b")
    rng = np.random.default_rng(5)
    prompts = [list(rng.integers(1, cfg.vocab_size, 9)) for _ in range(3)]

    def outs(chunk):
        eng = ServeEngine(cfg, batch_slots=2, max_len=64, params=params,
                          prefill_chunk=chunk)
        reqs = [Request(i, prompt=p, max_new=4) for i, p in
                enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return [r.out for r in reqs]

    assert outs(2) == outs(9) == outs(64)


def test_forced_qos_swap_crosses_kvq_boundary():
    cfg, params = setup("gemma2-27b")
    table = serving_table(cfg, slots=4, max_len=64)
    names = [v.name for v in table.variants]
    assert names[0] == "precise" and any(
        v.knobs.kv_quant for v in table.variants), names
    most = len(table) - 1

    # impossible target -> first decision jumps to most-approximate (Fig. 3),
    # crossing the kv_quant boundary with requests mid-decode. min_samples=4:
    # with decision_interval 0 the window resets every step, so the tail
    # estimate must resolve from one step's worth of samples (4 slots).
    # max_reclaim=0: no chips to shuffle before variant steps (single host)
    monitor = LatencyMonitor(qos_target_s=1e-7, window=256, min_samples=4)
    runtime = PliantRuntime(table, monitor,
                            ControllerConfig(decision_interval_s=0.0,
                                             max_reclaim=0))
    eng = ServeEngine(cfg, batch_slots=4, max_len=64, params=params,
                      runtime=runtime)
    reqs = [Request(i, prompt=[3 + i, 11, 7], max_new=10) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert eng.active_variant == most
    assert eng.swaps and eng.swaps[0][1] == most
    assert eng.swaps[0][0] < len(eng.step_latencies), \
        "swap must happen mid-run, not after drain"
    assert all(r.done and len(r.out) == 10 for r in reqs), \
        "decode must continue across the swap"
    kv = [c for c in eng.caches if isinstance(c, KVCache)]
    assert kv and all(c.k.dtype == jnp.int8 for c in kv), \
        "crossing into kv_quant must convert the KV rings to int8"
    assert any(h["action"] == "set_most_approx" for h in runtime.history)

    # relax the target -> controller steps back toward precise one variant
    # per decision, crossing the kv_quant boundary in the other direction
    monitor.qos_target_s = 1e9
    guard = 0
    while eng.active_variant != 0 and guard < 20:
        more = [Request(100 + guard * 10 + i, prompt=[2 + i, 5], max_new=10)
                for i in range(4)]
        for r in more:
            eng.submit(r)
        eng.run()
        assert all(r.done for r in more)
        guard += 1
    assert eng.active_variant == 0, runtime.history
    kv = [c for c in eng.caches if isinstance(c, KVCache)]
    assert all(c.k.dtype == jnp.float32 for c in kv), \
        "leaving kv_quant must convert the KV rings back"
    assert any(h["action"] == "step_toward_precise" for h in runtime.history)

    # a request served entirely under the restored precise variant matches
    # the seed token-by-token warmup exactly
    late = Request(999, prompt=[9, 8, 7], max_new=6)
    eng.submit(late)
    eng.run()
    assert late.out == greedy_warmup_ref(cfg, params, late.prompt, 6)


def test_serving_table_from_explorer():
    """One source of truth: serving variants come from the explorer grid —
    ordered precise-first, no train-only knobs, with serve-side kv_quant."""
    cfg, _ = setup("gemma2-27b")
    table = serving_table(cfg, slots=4, max_len=64)
    assert table.variants[0].knobs.is_precise()
    for v in table.variants:
        assert v.knobs.token_drop == 0 and v.knobs.layer_skip == 0
        assert v.knobs.sync_period == 1 and v.knobs.grad_compress == "none"
    losses = [v.quality_loss for v in table.variants]
    assert losses == sorted(losses)
    assert any(v.knobs.kv_quant for v in table.variants)
