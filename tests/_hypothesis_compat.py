"""Use hypothesis when installed; otherwise degrade gracefully.

The container image this repo pins cannot reach PyPI, so ``hypothesis`` (a
dev-extra, see requirements-dev.txt) may be absent. Importing this module
instead of ``hypothesis`` directly keeps ``test_core.py``/``test_substrate.py``
collectable either way: with hypothesis the property tests run for real;
without it only those tests are skipped — the rest of the module still runs.
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Opaque stand-in: builds on attribute access / call so strategy
        expressions like ``st.integers(1, 4).map(f)`` evaluate at collection
        time; the decorated test never runs (it is marked skipped)."""

        def __call__(self, *args, **kwargs):
            return _Strategy()

        def __getattr__(self, name):
            return _Strategy()

    st = _Strategy()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed "
                                       "(see requirements-dev.txt)")

    def settings(*args, **kwargs):
        return lambda fn: fn
