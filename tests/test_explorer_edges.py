"""Pure-python edge cases for ``explorer.pareto_front`` and
``knobs.keep_groups`` — degenerate inputs the property/grid tests never hit."""
import pytest

from repro.approx.knobs import keep_groups
from repro.core.explorer import pareto_front


# ------------------------------------------------------------ pareto_front --

def test_pareto_front_duplicate_points_kept_once():
    pts = [(0.1, 1.0), (0.1, 1.0), (0.1, 1.0)]
    front = pareto_front(pts)
    assert len(front) == 1
    assert pts[front[0]] == (0.1, 1.0)


def test_pareto_front_all_dominated_by_one():
    # index 2 dominates every other point on both axes
    pts = [(0.5, 3.0), (0.4, 2.0), (0.0, 1.0), (0.9, 5.0)]
    assert pareto_front(pts) == [2]


def test_pareto_front_empty_and_singleton():
    assert pareto_front([]) == []
    assert pareto_front([(0.3, 2.0)]) == [0]


def test_pareto_front_strict_frontier_sorted_by_quality_loss():
    # a real frontier: quality loss up, time down; dominated stragglers out
    pts = [(0.0, 5.0), (0.1, 3.0), (0.2, 4.0),   # (0.2, 4.0) dominated
           (0.3, 1.0), (0.3, 2.0)]               # tie on loss: faster wins
    front = pareto_front(pts)
    assert front == [0, 1, 3]
    losses = [pts[i][0] for i in front]
    assert losses == sorted(losses)
    times = [pts[i][1] for i in front]
    assert times == sorted(times, reverse=True)


# -------------------------------------------------------------- keep_groups --

def test_keep_groups_precise_keeps_all():
    assert keep_groups(6, 0.0) == tuple(range(6))
    assert keep_groups(6, -1.0) == tuple(range(6))


@pytest.mark.parametrize("n", [2, 3, 7, 16])
@pytest.mark.parametrize("skip", [0.1, 0.25, 0.5, 0.75, 0.95])
def test_keep_groups_first_and_last_always_kept(n, skip):
    kept = keep_groups(n, skip)
    assert kept[0] == 0
    assert kept[-1] == n - 1
    assert list(kept) == sorted(set(kept)), "sorted, unique"


def test_keep_groups_extreme_skip_clamps_to_two():
    assert keep_groups(12, 0.99) == (0, 11)
    assert keep_groups(2, 0.99) == (0, 1)


def test_keep_groups_tiny_stacks():
    # a 1-group model can never drop its only group
    assert keep_groups(1, 0.5) == (0,)
    # skips too small to remove a whole group keep everything
    assert keep_groups(4, 0.1) == (0, 1, 2, 3)
