"""The sharded fused decode path: on an 8-simulated-device mesh the paged
``ServeEngine`` must produce token-for-token the single-device kernel's (and
the gather reference's) output with the shard_map'd fused kernel actually
dispatched — plus the dispatch introspection (``explain_dispatch``, loud
gather fallback) and the per-device HBM bytes account."""
import pytest

from repro.kernels.paged_attention import (decode_hbm_bytes,
                                           sharded_decode_hbm_bytes)

ARCHS = ["phi4-mini-3.8b-smoke",   # MHA
         "gemma2-27b-smoke",       # GQA + local attention
         "zamba2-2.7b-smoke",      # hybrid attn/SSM
         "mamba2-780m-smoke"]      # pure SSM


def test_sharded_engine_token_parity(subproc):
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.models import attention as attn_mod
from repro.serve.engine import Request, ServeEngine

def drive(eng, cfg, n_req=6, prompt_len=10, max_new=5, shared=4):
    rng = np.random.default_rng(0)
    base = list(rng.integers(1, cfg.vocab_size, shared))
    reqs = [Request(i, prompt=base + list(
                rng.integers(1, cfg.vocab_size, prompt_len - shared)),
                    max_new=max_new) for i in range(n_req)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [list(r.out) for r in reqs]

mesh = make_mesh((2, 4), ("data", "model"))
for arch in %r:
    cfg = get_config(arch)
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    attn_mod.DISPATCH_COUNTS.clear()
    eng_s = ServeEngine(cfg, batch_slots=8, max_len=32, params=params,
                        mesh=mesh, paged=True, page_size=4,
                        use_kernel=True, kernel_interpret=True)
    assert eng_s.sharded_kernel, arch
    assert "shard_map'd" in eng_s.explain_dispatch(), \\
        (arch, eng_s.explain_dispatch())
    out_s = drive(eng_s, cfg)
    counts = dict(attn_mod.DISPATCH_COUNTS)
    has_attn = any(k != "mamba" for k in cfg.pattern)
    if has_attn:
        # the fused kernel IS the dispatched path, never the mesh gather
        assert counts.get("kernel_sharded", 0) > 0, (arch, counts)
    assert counts.get("gather_mesh", 0) == 0, (arch, counts)
    eng_1 = ServeEngine(cfg, batch_slots=8, max_len=32, params=params,
                        paged=True, page_size=4, use_kernel=True,
                        kernel_interpret=True)
    out_1 = drive(eng_1, cfg)
    eng_g = ServeEngine(cfg, batch_slots=8, max_len=32, params=params,
                        paged=True, page_size=4, use_kernel=False)
    out_g = drive(eng_g, cfg)
    assert out_s == out_1 == out_g, (arch, out_s, out_1, out_g)
    assert all(len(t) == 5 for t in out_s), out_s
    eng_s.pool.assert_consistent()
    print("PARITY_OK", arch)
print("ALL_OK")
""" % ARCHS, devices=8)
    assert "ALL_OK" in out
    for arch in ARCHS:
        assert f"PARITY_OK {arch}" in out


def test_mesh_gather_fallback_is_loud(subproc):
    out = subproc("""
import sys
sys.stderr = sys.stdout          # capture the fallback warning
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.models import attention as attn_mod
from repro.serve.engine import Request, ServeEngine

cfg = get_config("gemma2-27b-smoke")
params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
mesh = make_mesh((2, 4), ("data", "model"))
attn_mod.DISPATCH_COUNTS.clear()
# kernel explicitly off under a mesh -> gather path + one-line warning
eng = ServeEngine(cfg, batch_slots=8, max_len=32, params=params, mesh=mesh,
                  paged=True, page_size=4, use_kernel=False)
assert not eng.sharded_kernel
assert "gather" in eng.explain_dispatch(), eng.explain_dispatch()
r = Request(0, prompt=list(np.arange(1, 9)), max_new=3)
eng.submit(r)
eng.run()
assert len(r.out) == 3
assert attn_mod.DISPATCH_COUNTS.get("gather_mesh", 0) > 0, \\
    dict(attn_mod.DISPATCH_COUNTS)
assert attn_mod.DISPATCH_COUNTS.get("kernel_sharded", 0) == 0
print("FALLBACK_OK")
""", devices=8)
    assert "FALLBACK_OK" in out
    assert "GSPMD dense gather path" in out  # the loud one-liner fired


def test_explain_dispatch_single_device():
    from repro.configs import get_config
    from repro.models.attention import explain_dispatch

    cfg = get_config("gemma2-27b-smoke")
    s = explain_dispatch(cfg, None, batch_slots=4, use_kernel=True)
    assert "single device" in s and "fused" in s
    s = explain_dispatch(cfg, None, batch_slots=4, use_kernel=False)
    assert "single device" in s and "gather" in s


def test_plan_infeasible_reasons():
    """paged_decode_plan explains WHY it falls back (surfaced in the
    warning and the startup banner)."""
    from repro.configs import get_config
    from repro.dist.sharding import paged_decode_plan

    cfg = get_config("gemma2-27b-smoke")
    plan, reason = paged_decode_plan(cfg, None, 8)
    assert plan is None and "single device" in reason

    class FakeMesh:
        shape = {"model": 4}
    plan, reason = paged_decode_plan(cfg, FakeMesh(), 8)
    assert plan is None and reason


def test_per_device_bytes_scale_with_live_pages_per_shard():
    """The acceptance account: per-device fused-decode HBM traffic is
    1/n_shards of the whole-pool traffic and scales linearly with live
    pages per shard; the gather path has no such term."""
    G, hd, P, M, B = 2, 64, 8, 16, 8
    for n_shards in (2, 4):
        sparse = sharded_decode_hbm_bytes(8, P, G, hd, n_shards=n_shards,
                                          batch=B, n_heads=4, max_pages=M)
        dense = sharded_decode_hbm_bytes(32, P, G, hd, n_shards=n_shards,
                                         batch=B, n_heads=4, max_pages=M)
        ratio = dense / sparse
        assert 2.0 < ratio <= 4.0, (n_shards, ratio)
        # sharding divides the per-device traffic
        single = decode_hbm_bytes(32, P, G, hd, batch=B, n_heads=4,
                                  max_pages=M)
        assert dense < single
        assert dense == pytest.approx(single / n_shards, rel=0.05)


def test_sharded_bytes_match_per_shard_account():
    """sharded bytes == the single-device model applied to one shard's
    share of pages and slots — the definition the kernel bench persists."""
    import math
    live, P, G, hd, B, M, nsh = 24, 8, 2, 64, 8, 16, 4
    got = sharded_decode_hbm_bytes(live, P, G, hd, n_shards=nsh, batch=B,
                                   n_heads=4, max_pages=M)
    want = decode_hbm_bytes(math.ceil(live / nsh), P, G, hd,
                            batch=math.ceil(B / nsh), n_heads=4, max_pages=M)
    assert got == want
