"""Multi-device serving on an 8-device host mesh (subprocess, like
test_dist): engine with params sharded via ``dist.param_shardings`` and
caches via ``dist.cache_shardings`` produces the same greedy outputs as the
single-device engine, including across a kv_quant variant hot-swap."""
import pytest


def test_sharded_engine_matches_single_device(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.launch.serve import serving_table
from repro.models import api
from repro.models.attention import KVCache
from repro.serve.engine import Request, ServeEngine

cfg = get_config("phi4-mini-3.8b-smoke")
params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
table = serving_table(cfg, slots=4, max_len=32)
kvq_idx = len(table) - 1
assert table.variants[kvq_idx].knobs.kv_quant
rng = np.random.default_rng(1)
prompts = [list(rng.integers(1, cfg.vocab_size, 7)) for _ in range(9)]

def run(mesh):
    eng = ServeEngine(cfg, batch_slots=4, max_len=32, params=params,
                      table=table, mesh=mesh, prefill_chunk=3)
    reqs = [Request(i, prompt=p, max_new=5) for i, p in enumerate(prompts)]
    for r in reqs[:6]:
        eng.submit(r)
    eng.run()
    # hot-swap into the kv_quant variant (cache dtype conversion under the
    # mesh) and serve a second wave
    eng.set_variant(kvq_idx)
    for r in reqs[6:]:
        eng.submit(r)
    eng.run()
    return eng, [r.out for r in reqs]

eng_ref, ref = run(None)
mesh = make_mesh((2, 4), ("data", "model"))
eng_sh, got = run(mesh)
assert got == ref, (got, ref)
kv = [c for c in eng_sh.caches if isinstance(c, KVCache)]
assert kv
for c in kv:
    assert c.k.dtype == jnp.int8                       # converted under mesh
    assert c.k.sharding.spec == P(None, "data", "model", None, None), \\
        c.k.sharding                                    # dist.cache_shardings
ps = jax.tree.leaves(eng_sh.params)
assert any("model" in (s.sharding.spec or ()) for s in ps), \\
    "params must be TP-sharded"
print("DIST_SERVE_OK")
""", devices=8)
    assert "DIST_SERVE_OK" in out
