"""End-to-end behaviour: real training converges; the Pliant runtime switches
variants under contention without breaking convergence; quality loss of
approximate training is real but bounded (the paper's core trade-off)."""
import numpy as np
import pytest

from repro.launch import train as train_mod


def test_training_converges():
    loss = train_mod.main(["--arch", "phi4-mini-3.8b-smoke", "--steps", "40",
                           "--batch", "8", "--seq", "64", "--lr", "3e-3"])
    assert np.isfinite(loss)
    # random init sits at ~5.64 on this stream; the Markov/copy structure is
    # learnable down to ~5.4 at this scale — require clear movement
    assert loss < 5.52, loss


def test_pliant_training_converges_and_acts():
    import io, contextlib
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        loss = train_mod.main(["--arch", "phi4-mini-3.8b-smoke", "--steps",
                               "40", "--batch", "8", "--seq", "64", "--lr",
                               "3e-3", "--pliant",
                               "--decision-interval", "0.2"])
    out = buf.getvalue()
    assert np.isfinite(loss) and loss < 5.55
    assert "set_most_approx" in out        # contention burst triggered Pliant
    assert "pliant actions" in out


def test_approximation_quality_loss_bounded():
    """Train precise vs heavy-approximation for the same steps: approximate
    loss is worse (it IS an approximation) but within a few percent."""
    import jax, jax.numpy as jnp
    from repro.approx.knobs import ApproxKnobs
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models import api
    from repro.train import optim, step as step_mod

    cfg = get_config("mamba2-780m-smoke")
    data = SyntheticLM(DataConfig(cfg.vocab_size, 64, 8, seed=0))
    results = {}
    for name, knobs in [("precise", ApproxKnobs()),
                        ("approx", ApproxKnobs(matmul_precision="int8",
                                               token_drop=0.25))]:
        params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
        opt = optim.init_opt(params)
        step = jax.jit(step_mod.make_train_step(
            cfg, knobs, opt_cfg=optim.OptConfig(lr=3e-3, warmup=5,
                                                total_steps=60),
            remat="none"))
        losses = []
        for i in range(60):
            batch = {"tokens": jnp.asarray(data.batch(i))}
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        results[name] = np.mean(losses[-10:])
    qloss = (results["approx"] - results["precise"]) / results["precise"]
    assert results["approx"] < results["precise"] * 1.10, results
    assert np.isfinite(qloss)
