"""The ring-attention sequence-parallel prefill path: on an 8-simulated-device
mesh the ``ServeEngine``'s admission cells must produce token-for-token the
single-device engine's (and the GSPMD unsharded reference's) output with the
ring actually dispatched — plus the dispatch introspection
(``explain_prefill_dispatch``, loud unsharded fallback), the plan's
infeasibility reasons, the per-device cost model, and the flash kernel's
ragged-tail handling the ring path leans on."""
import math

import pytest

from repro.kernels.ring_attention import (prefill_attn_flops,
                                          prefill_hbm_bytes,
                                          sharded_prefill_attn_flops,
                                          sharded_prefill_hbm_bytes)

ARCHS = ["phi4-mini-3.8b-smoke",   # MHA
         "gemma2-27b-smoke",       # GQA + local attention
         "zamba2-2.7b-smoke",      # hybrid attn/SSM
         "mamba2-780m-smoke"]      # pure SSM


def test_ring_engine_token_parity(subproc):
    """Paged admission with ragged chunk boundaries (prompt 10 over chunk 8)
    and a shared prefix, all four architecture families: ring == single
    device == GSPMD unsharded, with the ring counted as the dispatched
    path."""
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.models import attention as attn_mod
from repro.serve.engine import Request, ServeEngine

def drive(eng, cfg, n_req=6, prompt_len=10, max_new=5, shared=4):
    rng = np.random.default_rng(0)
    base = list(rng.integers(1, cfg.vocab_size, shared))
    reqs = [Request(i, prompt=base + list(
                rng.integers(1, cfg.vocab_size, prompt_len - shared)),
                    max_new=max_new) for i in range(n_req)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [list(r.out) for r in reqs]

mesh = make_mesh((2, 4), ("data", "model"))
kw = dict(batch_slots=8, max_len=32, paged=True, page_size=4,
          prefill_chunk=8)
for arch in %r:
    cfg = get_config(arch)
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    attn_mod.DISPATCH_COUNTS.clear()
    eng_s = ServeEngine(cfg, params=params, mesh=mesh, use_kernel=True,
                        kernel_interpret=True, **kw)
    assert eng_s.sharded_prefill, arch
    assert "shard_map'd" in eng_s.explain_prefill_dispatch(), \\
        (arch, eng_s.explain_prefill_dispatch())
    out_s = drive(eng_s, cfg)
    counts = dict(attn_mod.DISPATCH_COUNTS)
    has_attn = any(k != "mamba" for k in cfg.pattern)
    if has_attn:
        # the ring IS the dispatched admission path, never the mesh gather
        assert counts.get("ring_prefill", 0) > 0, (arch, counts)
    assert counts.get("prefill_gather_mesh", 0) == 0, (arch, counts)
    eng_1 = ServeEngine(cfg, params=params, use_kernel=True,
                        kernel_interpret=True, **kw)
    out_1 = drive(eng_1, cfg)
    eng_g = ServeEngine(cfg, params=params, mesh=mesh, use_kernel=False,
                        **kw)
    out_g = drive(eng_g, cfg)
    assert out_s == out_1 == out_g, (arch, out_s, out_1, out_g)
    assert all(len(t) == 5 for t in out_s), out_s
    eng_s.pool.assert_consistent()
    print("PARITY_OK", arch)
print("ALL_OK")
""" % ARCHS, devices=8)
    assert "ALL_OK" in out
    for arch in ARCHS:
        assert f"PARITY_OK {arch}" in out


def test_ring_dense_engine_token_parity(subproc):
    """The dense (ring-buffer cache) engine's admission path dispatches the
    ring too — the concat [cache; chunk] route, not the paged gather."""
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.models import attention as attn_mod
from repro.serve.engine import Request, ServeEngine

def drive(eng, cfg):
    rng = np.random.default_rng(1)
    reqs = [Request(i, prompt=list(rng.integers(1, cfg.vocab_size, 10)),
                    max_new=4) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [list(r.out) for r in reqs]

mesh = make_mesh((2, 4), ("data", "model"))
kw = dict(batch_slots=4, max_len=32, prefill_chunk=8)
for arch in ("gemma2-27b-smoke", "zamba2-2.7b-smoke"):
    cfg = get_config(arch)
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    attn_mod.DISPATCH_COUNTS.clear()
    eng_s = ServeEngine(cfg, params=params, mesh=mesh, use_kernel=True,
                        kernel_interpret=True, **kw)
    assert eng_s.sharded_prefill, arch
    out_s = drive(eng_s, cfg)
    assert attn_mod.DISPATCH_COUNTS.get("ring_prefill", 0) > 0, \\
        dict(attn_mod.DISPATCH_COUNTS)
    eng_1 = ServeEngine(cfg, params=params, **kw)
    out_1 = drive(eng_1, cfg)
    assert out_s == out_1, (arch, out_s, out_1)
    print("DENSE_OK", arch)
print("ALL_OK")
""", devices=8)
    assert "ALL_OK" in out


def test_prefill_fallback_is_loud(subproc):
    out = subproc("""
import sys
sys.stderr = sys.stdout          # capture the fallback warning
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.models import attention as attn_mod
from repro.serve.engine import Request, ServeEngine

cfg = get_config("gemma2-27b-smoke")
params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
mesh = make_mesh((2, 4), ("data", "model"))
attn_mod.DISPATCH_COUNTS.clear()
# kernel explicitly off under a mesh -> unsharded admission + one-line warn
eng = ServeEngine(cfg, batch_slots=8, max_len=32, params=params, mesh=mesh,
                  paged=True, page_size=4, prefill_chunk=8, use_kernel=False)
assert not eng.sharded_prefill
assert "unsharded" in eng.explain_prefill_dispatch(), \\
    eng.explain_prefill_dispatch()
r = Request(0, prompt=list(np.arange(1, 11)), max_new=3)
eng.submit(r)
eng.run()
assert len(r.out) == 3
assert attn_mod.DISPATCH_COUNTS.get("prefill_gather_mesh", 0) > 0, \\
    dict(attn_mod.DISPATCH_COUNTS)
assert attn_mod.DISPATCH_COUNTS.get("ring_prefill", 0) == 0
print("FALLBACK_OK")
""", devices=8)
    assert "FALLBACK_OK" in out
    assert "GSPMD unsharded path" in out   # the loud one-liner fired


def test_ring_numerics_direct(subproc):
    """ring_chunk_attention vs a masked-softmax oracle on raw arrays:
    position holes, causal striping, window mode, softcap, int8 KV."""
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.dist.sharding import prefill_plan
from repro.kernels.ring_attention import ring_chunk_attention
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
cfg = get_config("gemma2-27b-smoke")
plan, reason = prefill_plan(cfg, mesh, 10)
assert plan is not None, reason
assert plan.n_shards == 2 and plan.seq_axis == "data", vars(plan)

B, C, G, R, hd, L = 1, 10, 2, 2, 16, 42
rng = np.random.default_rng(0)
q = jnp.asarray(rng.normal(size=(B, C, G, R, hd)) * 0.3, jnp.float32)
q_pos = jnp.asarray(np.broadcast_to(np.arange(32, 32 + C), (B, C)),
                    jnp.int32)
kv_pos = np.broadcast_to(np.arange(L), (B, L)).copy()
kv_pos[:, 5:9] = -1                      # unmapped hole
kv_pos = jnp.asarray(kv_pos, jnp.int32)

def ref(q, k, v, qp, kvp, window, cap, kv_scale):
    dq = (lambda a: a.astype(jnp.float32) * kv_scale) if kv_scale else \\
        (lambda a: a.astype(jnp.float32))
    s = jnp.einsum("bcgrd,blgd->bgrcl", q, dq(k)) * hd ** -0.5
    if cap:
        s = cap * jnp.tanh(s / cap)
    qe, ke = qp[:, None, None, :, None], kvp[:, None, None, None, :]
    mask = (ke >= 0) & (ke <= qe)
    if window:
        mask &= ke > qe - window
    p = jax.nn.softmax(jnp.where(mask, s, -1e30), axis=-1)
    return jnp.einsum("bgrcl,blgd->bcgrd", p, dq(v))

for window in (0, 8):
    for cap in (0.0, 30.0):
        k = jnp.asarray(rng.normal(size=(B, L, G, hd)) * 0.3, jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, L, G, hd)), jnp.float32)
        o = ring_chunk_attention(q, k, v, q_pos, kv_pos, mesh=mesh,
                                 plan=plan, window=window, cap=cap,
                                 interpret=True)
        want = ref(q, k, v, q_pos, kv_pos, window, cap, 0.0)
        err = float(jnp.max(jnp.abs(o - want)))
        assert err < 1e-5, (window, cap, err)
ki = jnp.asarray(rng.integers(-127, 128, (B, L, G, hd)), jnp.int8)
vi = jnp.asarray(rng.integers(-127, 128, (B, L, G, hd)), jnp.int8)
o = ring_chunk_attention(q, ki, vi, q_pos, kv_pos, mesh=mesh, plan=plan,
                         kv_scale=0.05, interpret=True)
want = ref(q, ki, vi, q_pos, kv_pos, 0, 0.0, 0.05)
err = float(jnp.max(jnp.abs(o - want)))
assert err < 1e-5, err
print("NUMERICS_OK")
""", devices=8)
    assert "NUMERICS_OK" in out


def test_explain_prefill_dispatch_single_device():
    from repro.configs import get_config
    from repro.models.attention import explain_prefill_dispatch

    cfg = get_config("gemma2-27b-smoke")
    s = explain_prefill_dispatch(cfg, None, chunk_len=16, use_kernel=True)
    assert "single device" in s
    s = explain_prefill_dispatch(cfg, None, chunk_len=16, use_kernel=False)
    assert "single device" in s


def test_prefill_plan_infeasible_reasons():
    """prefill_plan explains WHY it falls back (surfaced in the warning and
    the startup banner)."""
    from repro.configs import get_config
    from repro.dist.sharding import prefill_plan

    cfg = get_config("gemma2-27b-smoke")
    plan, reason = prefill_plan(cfg, None, 16)
    assert plan is None and "single device" in reason

    class FakeMesh:
        shape = {"model": 4}
    plan, reason = prefill_plan(cfg, FakeMesh(), 16)
    assert plan is None and "batch mesh axis" in reason

    class WideMesh:
        shape = {"data": 64}
    plan, reason = prefill_plan(cfg, WideMesh(), 16)
    assert plan is None and "chunk_len" in reason


def test_prefill_per_device_work_scales():
    """The acceptance account: per-device ring FLOPs and HBM bytes at the
    32k target shape are ~1/n_shards of the unsharded chunk's."""
    C, L, H, G, hd = 2048, 32768, 16, 8, 128
    total_f = prefill_attn_flops(C, L, H, hd)
    total_b = prefill_hbm_bytes(C, L, G, hd, n_heads=H)
    for n in (2, 4, 8):
        per_f = sharded_prefill_attn_flops(C, L, H, hd, n_shards=n)
        per_b = sharded_prefill_hbm_bytes(C, L, G, hd, n_shards=n,
                                          n_heads=H)
        assert 0.8 * n <= total_f / per_f <= n, (n, total_f / per_f)
        assert 0.8 * n <= total_b / per_b <= n, (n, total_b / per_b)


def test_sharded_prefill_bytes_match_per_shard_account():
    """sharded bytes == the single-device model applied to one shard's
    resident queries and initial K/V shard — the definition the kernel
    bench persists."""
    C, L, G, hd, H, n = 100, 1000, 4, 64, 8, 8
    got = sharded_prefill_hbm_bytes(C, L, G, hd, n_shards=n, n_heads=H)
    want = prefill_hbm_bytes(math.ceil(C / n), math.ceil(L / n), G, hd,
                             n_heads=H)
    assert got == want


def test_flash_attention_ragged_tail():
    """Satellite: chunk lengths that are not block-size multiples are padded
    and masked, not silently miscomputed."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention

    B, H, KVH, hd = 1, 4, 2, 32
    key = jax.random.PRNGKey(0)
    for Sq, Skv in ((100, 100), (130, 130), (37, 64)):
        kq, kk, kv = jax.random.split(jax.random.fold_in(key, Sq), 3)
        q = jax.random.normal(kq, (B, H, Sq, hd)) * 0.3
        k = jax.random.normal(kk, (B, KVH, Skv, hd)) * 0.3
        v = jax.random.normal(kv, (B, KVH, Skv, hd))
        got = flash_attention(q, k, v, causal=False, interpret=True,
                              bq=64, bk=64)
        want = ref.mha_ref(q, k, v, causal=False)
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 1e-5, (Sq, Skv, err)
    # causal + window on a ragged length (equal Sq/Skv: flash's causal mask
    # is prefill-anchored at position 0, unlike mha_ref's decode alignment)
    kq, kk, kv = jax.random.split(jax.random.fold_in(key, 99), 3)
    q = jax.random.normal(kq, (B, H, 100, hd)) * 0.3
    k = jax.random.normal(kk, (B, KVH, 100, hd)) * 0.3
    v = jax.random.normal(kv, (B, KVH, 100, hd))
    got = flash_attention(q, k, v, causal=True, window=16, interpret=True,
                          bq=64, bk=64)
    want = ref.mha_ref(q, k, v, causal=True, window=16)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-5
