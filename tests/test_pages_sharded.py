"""Slot-affinity invariants of the sharded page pool: under ANY interleaving
of admissions, frees, window releases, reclaims, and replenish churn, every
slot's pages stay on its owning shard and no page ever migrates — the
host-side contract the shard_map'd fused decode kernel compiles against
(``models.attention._sharded_write_attend`` rebases block tables assuming
device-local pages)."""
import pytest

from repro.serve.pages import PagePool, spec_for
from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

SLOTS, MAX_LEN, PSIZE, NSH = 8, 32, 4, 4


def mk_pool(n_shards=NSH, slots=SLOTS, n_pages=0):
    spec = spec_for(slots, MAX_LEN, page_size=PSIZE, n_pages=n_pages,
                    n_shards=n_shards)
    return PagePool(spec, slots)


def check_affinity(pool):
    """assert_consistent plus the explicit cross-shard-migration audit."""
    pool.assert_consistent()
    for slot, pages in enumerate(pool.slot_pages):
        for p in pages:
            assert pool.page_shard(p) == pool.slot_shard(slot)
    for s, dq in enumerate(pool._free):
        assert all(pool.page_shard(p) == s for p in dq)
    for e in pool.index.values():
        assert len({pool.page_shard(p) for p in e.pages}) == 1


def test_spec_sizing_divides_shards():
    spec = mk_pool().spec
    assert spec.n_pages % NSH == 0
    assert spec.usable == spec.n_pages - NSH
    # one null sentinel per shard, never allocatable
    pool = mk_pool()
    nulls = {s * spec.shard_pages for s in range(NSH)}
    assert not nulls & set(pool.free)


def test_admit_places_pages_on_owning_shard():
    pool = mk_pool()
    for slot in range(SLOTS):
        plan = pool.admit(slot, list(range(10 + slot)), "tag")
        assert plan is not None
        shard = pool.slot_shard(slot)
        assert all(pool.page_shard(p) == shard
                   for p in pool.slot_pages[slot])
    check_affinity(pool)


def test_free_returns_pages_to_owning_shard():
    pool = mk_pool()
    for slot in range(SLOTS):
        assert pool.admit(slot, list(range(12)), slot) is not None
    before = [len(dq) for dq in pool._free]
    for slot in range(SLOTS):
        pool.free_slot(slot)
    pool.flush_prefixes()
    check_affinity(pool)
    after = [len(dq) for dq in pool._free]
    # every shard got exactly its own slots' pages back
    assert after == [b + 3 * (SLOTS // NSH) for b in before]


def test_decode_growth_stays_on_shard():
    pool = mk_pool()
    for slot in range(SLOTS):
        assert pool.admit(slot, list(range(6)), "t") is not None
        for pos in range(6, 6 + 3 * PSIZE):
            pool.ensure_decode_page(slot, pos)
        check_affinity(pool)


def test_release_window_and_replenish_never_migrate():
    pool = mk_pool()
    for slot in range(SLOTS):
        assert pool.admit(slot, list(range(16)), slot % 2) is not None
    owner = {p: pool.page_shard(p)
             for pages in pool.slot_pages for p in pages}
    for slot in range(SLOTS):
        pool.release_window_pages(slot, min_pos=2 * PSIZE - 1)
        check_affinity(pool)
    pool.replenish(low=pool.spec.usable, high=pool.spec.usable)
    check_affinity(pool)
    # page->shard is a static function of the id: nothing can have moved
    for p, s in owner.items():
        assert pool.page_shard(p) == s


def test_pressure_evicts_only_on_the_starved_shard():
    # small pool: 12 pages per shard (1 null + 11 usable)
    pool = mk_pool(n_pages=48)
    # pin prefix entries on every shard, then free the slots (index-only)
    for slot in range(SLOTS):
        plan = pool.admit(slot, list(range(8)), slot)
        for b in plan.register:
            pool.register_prefix(slot, list(range(8)), slot, b)
        pool.free_slot(slot)
    assert len(pool.index) >= NSH
    per_shard = lambda: [sum(1 for e in pool.index.values()
                             if pool.page_shard(e.pages[0]) == s)
                         for s in range(NSH)]
    before = per_shard()
    # a full-length admission on a shard-0 slot overruns its 7 free pages:
    # the supply loop must evict shard 0's own prefix entries, nobody else's
    shard0_slots = [s for s in range(SLOTS) if pool.slot_shard(s) == 0]
    assert pool.admit(shard0_slots[0], list(range(MAX_LEN)), "fat") is not None
    check_affinity(pool)
    after = per_shard()
    assert after[0] < before[0]
    assert after[1:] == before[1:]


def test_single_shard_pool_unchanged():
    # n_shards=1 keeps the legacy single-free-list behavior byte-identical
    pool = mk_pool(n_shards=1)
    assert pool.spec.shard_pages == pool.spec.n_pages
    assert all(pool.slot_shard(s) == 0 for s in range(SLOTS))
    assert pool.admit(0, list(range(10)), "t") is not None
    check_affinity(pool)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, SLOTS - 1),
                          st.integers(1, MAX_LEN - 2 * PSIZE),
                          st.integers(0, 2)),
                min_size=1, max_size=40))
def test_any_interleaving_keeps_slot_affinity(ops):
    """admit/decode/free/release/reclaim/replenish in any order: the pool
    stays consistent and no slot ever maps a page off its shard."""
    pool = mk_pool()
    pos = [0] * SLOTS
    for op, slot, length, tag in ops:
        if op == 0 and not pool.slot_pages[slot]:                  # admit
            if pool.admit(slot, list(range(length)), tag) is not None:
                pos[slot] = length
        elif op == 1 and pool.slot_pages[slot]:                    # decode
            for p in range(pos[slot],
                           min(pos[slot] + PSIZE + 1, MAX_LEN)):
                pool.ensure_decode_page(slot, p)
            pos[slot] = min(pos[slot] + PSIZE + 1, MAX_LEN)
        elif op == 2:                                              # free
            pool.free_slot(slot)
        elif op == 3 and pool.slot_pages[slot]:                    # window
            pool.release_window_pages(slot, min_pos=length - 1)
        elif op == 4:                                              # reclaim
            pool.set_reclaimed(tag)
        elif op == 5:                                              # bg churn
            pool.replenish()
        check_affinity(pool)
