"""Serving engine: continuous batching matches single-request greedy
decoding; serving approximation variants run and stay close."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx.knobs import ApproxKnobs
from repro.configs import get_config
from repro.models import api, lm
from repro.serve.engine import Request, ServeEngine

CFG = get_config("gemma2-27b-smoke")
PARAMS = api.init(CFG, jax.random.PRNGKey(0), jnp.float32)


def greedy_ref(prompt, n, max_len=64):
    caches = lm.init_caches(CFG, 1, max_len, dtype=jnp.float32)
    step = jax.jit(lambda p, t, po, c: lm.decode_step(p, t, po, c, CFG))
    out, cursor, cur, pos = [], 0, prompt[0], 0
    while len(out) < n:
        logits, caches = step(PARAMS, jnp.asarray([[cur]]),
                              jnp.asarray([pos]), caches)
        pos += 1
        if cursor + 1 < len(prompt):
            cursor += 1
            cur = prompt[cursor]
            continue
        cur = int(jnp.argmax(logits[0]))
        out.append(cur)
    return out


def test_continuous_batching_matches_greedy():
    eng = ServeEngine(CFG, batch_slots=3, max_len=64, params=PARAMS)
    reqs = [Request(uid, prompt=[1 + uid, 2, 3 + uid], max_new=6)
            for uid in range(5)]           # 5 requests through 3 slots
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert r.done
        want = greedy_ref(r.prompt, 6)
        assert r.out == want, (r.uid, r.out, want)


def test_slot_reuse_isolated():
    """A recycled slot must not see the previous request's KV entries."""
    eng = ServeEngine(CFG, batch_slots=1, max_len=64, params=PARAMS)
    a = Request(0, prompt=[5, 6, 7], max_new=4)
    b = Request(1, prompt=[9, 10], max_new=4)
    eng.submit(a)
    eng.submit(b)
    eng.run()
    assert b.out == greedy_ref(b.prompt, 4)


def test_temperature_sampling():
    """temperature=0 is greedy; temperature>0 samples from the softmax with a
    per-engine PRNG: deterministic per seed, different across seeds."""
    def outs(temperature, seed):
        eng = ServeEngine(CFG, batch_slots=2, max_len=64, params=PARAMS,
                          temperature=temperature, seed=seed)
        reqs = [Request(uid, prompt=[4 + uid, 9], max_new=8)
                for uid in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done for r in reqs)
        return [r.out for r in reqs]

    greedy = outs(0.0, 0)
    assert greedy == outs(0.0, 7), "greedy must ignore the sampling seed"
    assert greedy == [greedy_ref(r, 8) for r in ([4, 9], [5, 9], [6, 9])]
    hot = outs(1.0, 0)
    assert hot == outs(1.0, 0), "same seed must reproduce sampled outputs"
    assert hot != greedy, "T=1 sampling should diverge from argmax"
    assert hot != outs(1.0, 1), "different seeds should diverge"


def test_int8_kv_quant_variant_close():
    precise = ServeEngine(CFG, batch_slots=2, max_len=64, params=PARAMS)
    approx = ServeEngine(CFG, batch_slots=2, max_len=64, params=PARAMS,
                         knobs=ApproxKnobs(kv_quant=True))
    outs = {}
    for eng, name in [(precise, "p"), (approx, "a")]:
        reqs = [Request(uid, prompt=[2 + uid, 3], max_new=8)
                for uid in range(2)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        outs[name] = [r.out for r in reqs]
    agree = np.mean([a == b for ra, rb in zip(outs["p"], outs["a"])
                     for a, b in zip(ra, rb)])
    assert agree >= 0.5, (agree, outs)    # bounded quality loss, not garbage
