"""PagePool consistency under elastic shrink/grow (``migrate``).

``PagePool.migrate`` re-homes every live slot's pages into a fresh pool
with a different shard count (the slot-affinity layout after a capacity
event). These tests interleave the full allocator surface — grouped
admissions, prefix sharing, decode growth, window release, completions,
Pliant reclaim, and external quota cuts — with shard-count changes in
both directions, asserting after every migration that

* ``assert_consistent`` holds (no leaked, double-owned, or cross-shard
  pages; free lists exact);
* the logical block layout is preserved bit-for-bit (``perm`` names a
  valid source for every mapped page, empties stay empty);
* shared (prefix-hit) pages are duplicated, never aliased across slots
  that land on different shards;
* the prefix index is evicted (cold misses), never migrated.

The deterministic interleavings always run; the randomized schedules are
hypothesis-gated (skipped when hypothesis is absent — see
``_hypothesis_compat``).
"""
import numpy as np
import pytest

from repro.serve.pages import PagePool, spec_for

from _hypothesis_compat import given, settings, st

SLOTS = 4
MAX_LEN = 32
P = 4                                # page_size
MAX_PAGES = MAX_LEN // P


def make_pool(n_shards, reclaim_quantum=2):
    spec = spec_for(SLOTS, MAX_LEN, P, n_shards=n_shards)
    return PagePool(spec, SLOTS, reclaim_quantum=reclaim_quantum)


def check_migration(old, new, perm):
    """The full migration contract between ``old`` and ``(new, perm)``."""
    new.assert_consistent()
    assert new.index == {}, "prefix entries are evicted, never migrated"
    live = 0
    for slot in range(SLOTS):
        for lp in range(MAX_PAGES):
            o, n = int(old.blocks[slot, lp]), int(new.blocks[slot, lp])
            assert (o == 0) == (n == 0), (slot, lp, o, n)
            if o:
                live += 1
                assert perm[n] == o, (slot, lp, "perm must name the source")
                assert new.page_shard(n) == new.slot_shard(slot), \
                    (slot, n, "re-homed page off its slot's shard")
    dst = np.flatnonzero(perm >= 0)
    assert len(dst) == live, "every live mapping gets its own physical page"
    # a shared source may fan out to several destinations (CoW collapse),
    # but no destination is written twice and none is a null page
    nulls = {s * new.spec.shard_pages for s in range(new.spec.n_shards)}
    assert not (set(dst.tolist()) & nulls)
    assert old.capacity_cut == new.capacity_cut
    assert new.reclaimed == min(old.reclaimed, new.max_quanta)
    assert new.stats["elastic_migrations"] == \
        old.stats["elastic_migrations"] + 1


def test_migrate_preserves_live_layout_and_duplicates_shared_pages():
    pool = make_pool(1)
    rng = np.random.default_rng(0)
    base = list(rng.integers(1, 999, 8))          # two full shared pages
    pool.admit(0, base + [7, 7], tag=0)
    pool.register_prefix(0, base + [7, 7], 0, 8)
    plan = pool.admit(1, base + [9], tag=0)       # prefix hit: shares 2 pages
    assert plan.shared_tokens == 8
    shared = set(pool.slot_pages[0][:2])
    assert shared == set(pool.slot_pages[1][:2])
    pool.admit(2, [1, 2, 3], tag=0, reserve_tokens=8)   # grouped/speculative
    pool.admit(3, [5], tag=0)
    pool.ensure_decode_page(3, 4)                 # decode growth
    pool.assert_consistent()

    new, perm = pool.migrate(spec_for(SLOTS, MAX_LEN, P, n_shards=2))
    check_migration(pool, new, perm)
    # slots 0 and 1 land on shard 0, slots 2 and 3 on shard 1 — the shared
    # prefix pages were duplicated (one private copy per slot), so the two
    # copies are distinct physical pages with refcount 1 each
    a, b = new.slot_pages[0][:2], new.slot_pages[1][:2]
    assert not (set(a) & set(b)), "CoW collapses to copies on migration"
    assert all(int(new.ref[p]) == 1 for p in a + b)
    assert [perm[p] for p in a] == [perm[p] for p in b], \
        "both copies source the same old pages"

    # and back down to one shard: still exact
    back, perm2 = new.migrate(spec_for(SLOTS, MAX_LEN, P, n_shards=1))
    check_migration(new, back, perm2)


def test_migrate_carries_budget_floors_and_serves_after():
    pool = make_pool(2)
    pool.admit(0, [1, 2, 3, 4, 5], tag=0)
    pool.set_reclaimed(1)
    pool.set_capacity_cut(2)
    new, perm = pool.migrate(spec_for(SLOTS, MAX_LEN, P, n_shards=4))
    check_migration(pool, new, perm)
    assert new.capacity_cut == 2 and new.reclaimed >= 0
    # the migrated pool keeps serving: admissions, growth, frees
    assert new.admit(1, [9, 8, 7, 6, 5, 4], tag=0) is not None \
        or new.limit == 0
    new.set_capacity_cut(0)
    new.set_reclaimed(0)
    assert new.admit(2, [4, 4, 4], tag=0) is not None
    new.ensure_decode_page(2, 4)
    new.free_slot(0)
    new.assert_consistent()


def test_migrate_full_pool_no_leaks():
    """Every slot holding a full sequence — the worst-case live set the
    sizing contract (``spec_for``) promises always fits — survives shrink
    to every shard count that divides the slots."""
    for target in (1, 2, 4):
        pool = make_pool(1)
        for s in range(SLOTS):
            assert pool.admit(s, list(range(1, MAX_LEN)), tag=0) is not None
        pool.assert_consistent()
        new, perm = pool.migrate(spec_for(SLOTS, MAX_LEN, P,
                                          n_shards=target))
        check_migration(pool, new, perm)
        for s in range(SLOTS):
            new.free_slot(s)
        assert new.used == 0
        new.assert_consistent()


def test_migrate_rejects_shape_drift():
    pool = make_pool(1)
    with pytest.raises(AssertionError):
        pool.migrate(spec_for(SLOTS, MAX_LEN, page_size=8, n_shards=1))
    with pytest.raises(AssertionError):
        pool.migrate(spec_for(SLOTS, MAX_LEN * 2, P, n_shards=1))


# ------------------------------------------------------ random schedules --

OPS = ("admit", "admit_shared", "grow", "window", "free", "reclaim",
       "quota", "migrate")


def run_schedule(codes, seed):
    """Interpret ``codes`` as an op schedule over a live pool, migrating
    across shard counts whenever a migrate op appears; audit after every
    step and verify the full migration contract at each re-home."""
    rng = np.random.default_rng(seed)
    pool = make_pool(1)
    pos = {}                                   # slot -> next decode position
    shards = (1, 2, 4)
    migrations = 0
    for code in codes:
        op = OPS[code % len(OPS)]
        slot = int(rng.integers(SLOTS))
        if op in ("admit", "admit_shared") and slot not in pos:
            if op == "admit_shared":
                prompt = [11, 22, 33, 44] + \
                    list(rng.integers(1, 999, int(rng.integers(1, 5))))
            else:
                prompt = list(rng.integers(1, 999,
                                           int(rng.integers(1, MAX_LEN - 8))))
            plan = pool.admit(slot, prompt, tag=0,
                              reserve_tokens=int(rng.integers(0, 9)))
            if plan is not None:
                pos[slot] = len(prompt)
                full = (len(prompt) // P) * P
                if full:
                    pool.register_prefix(slot, prompt, 0, min(full, P))
        elif op == "grow" and slot in pos and pos[slot] < MAX_LEN - 1:
            pos[slot] += 1
            pool.ensure_decode_page(slot, pos[slot])
        elif op == "window" and slot in pos:
            pool.release_window_pages(slot, max(pos[slot] - 8, 0))
        elif op == "free" and slot in pos:
            pool.free_slot(slot)
            del pos[slot]
        elif op == "reclaim":
            pool.set_reclaimed(int(rng.integers(0, pool.max_quanta + 1)))
        elif op == "quota":
            pool.set_capacity_cut(int(rng.integers(0, 3)))
        elif op == "migrate":
            target = shards[int(rng.integers(len(shards)))]
            new, perm = pool.migrate(spec_for(SLOTS, MAX_LEN, P,
                                              n_shards=target))
            check_migration(pool, new, perm)
            pool = new
            migrations += 1
        pool.assert_consistent()
    # drain: every live slot frees cleanly, nothing stranded
    for slot in list(pos):
        pool.free_slot(slot)
    pool.flush_prefixes()
    assert pool.used == 0, "leaked pages after drain"
    pool.assert_consistent()
    return migrations


def test_deterministic_interleavings():
    """A fixed dense schedule that hits every op around two migrations —
    runs with or without hypothesis."""
    codes = [0, 1, 2, 2, 7, 1, 0, 3, 5, 7, 2, 4, 6, 0, 7, 2, 4, 7, 5, 6,
             0, 1, 7, 4, 4]
    assert run_schedule(codes, seed=13) >= 2


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, len(OPS) - 1), min_size=4, max_size=60),
       st.integers(0, 2 ** 16))
def test_random_interleavings_never_corrupt(codes, seed):
    run_schedule(codes, seed)
