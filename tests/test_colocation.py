"""Colocation model calibration + end-to-end Pliant simulation: reproduces
the paper's headline claims (precise violates QoS by the published bands;
Pliant meets QoS at <=5% quality loss; round-robin keeps losses balanced)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core.colocation import (SERVICES, BatchJob, archetype_jobs,
                                   interference_of, simulate)
from repro.core.explorer import explore

# paper violation bands under precise colocation (Fig. 5): memcached
# 1.46-3.8x, NGINX 2.1-9.8x, MongoDB 2.08-5.91x — our calibration targets
# "considerable violation" within loose versions of those bands
BANDS = {"token-serve": (1.2, 4.5), "search-prefill": (1.4, 10.5),
         "embed-api": (1.1, 6.5)}


def _job(arch="phi4-mini-3.8b", serving=False, seed=0):
    cfg = get_config(arch)
    table = explore(cfg, SHAPES["train_4k"], serving=serving)
    return BatchJob(name=arch, table=table, total_work=300.0)


@pytest.mark.parametrize("svc_name", list(SERVICES))
def test_precise_colocation_violates_in_band(svc_name):
    svc = SERVICES[svc_name]
    lo, hi = BANDS[svc_name]
    for arch in ["phi4-mini-3.8b", "mamba2-780m", "olmoe-1b-7b"]:
        job = _job(arch)
        res = simulate(svc, [job], precise_only=True, horizon_s=60, seed=1)
        mult = np.median([p.p99 for p in res.timeline]) / svc.qos_target_s
        assert lo <= mult <= hi, (svc_name, arch, mult)


@pytest.mark.parametrize("svc_name", list(SERVICES))
def test_pliant_meets_qos(svc_name):
    """Paper Fig. 5 metric: the run's overall tail latency sits at/below QoS
    (bars under the line), with most intervals individually met."""
    svc = SERVICES[svc_name]
    job = _job("phi4-mini-3.8b")
    res = simulate(svc, [job], horizon_s=360, seed=2)
    median_p99 = float(np.median([p.p99 for p in res.timeline[3:]]))
    assert median_p99 <= svc.qos_target_s * 1.02, (svc_name, median_p99)
    assert res.qos_met_frac > 0.7, (svc_name, res.qos_met_frac)
    assert job.quality_loss <= 0.055, job.quality_loss


def test_pliant_quality_loss_near_paper_average():
    """Across services x archs, mean loss ~2% (paper: 2.1%), max <= 5.5%."""
    losses = []
    for svc_name in SERVICES:
        for arch in ["phi4-mini-3.8b", "olmoe-1b-7b", "mamba2-780m",
                     "gemma2-27b"]:
            job = _job(arch)
            res = simulate(SERVICES[svc_name], [job], horizon_s=300,
                           seed=hash((svc_name, arch)) % 2**31)
            losses.append(job.quality_loss)
    assert np.mean(losses) < 0.04, np.mean(losses)
    assert max(losses) <= 0.055, max(losses)


def test_lenient_service_allows_precise_mode():
    """MongoDB-analogue at moderate load (paper Fig. 8: below ~80-85% load
    MongoDB lets colocated apps run precise): significant precise fraction,
    strictly more than under the strict per-token service."""
    svc = SERVICES["embed-api"]
    job = _job("mamba2-780m")
    res = simulate(svc, [job], horizon_s=300, seed=3, load_frac=0.55)
    precise_frac = np.mean([p.variants[0] == 0 for p in res.timeline])
    strict_job = _job("mamba2-780m")
    res2 = simulate(SERVICES["token-serve"], [strict_job], horizon_s=300,
                    seed=3, load_frac=0.775)
    strict_frac = np.mean([p.variants[0] == 0 for p in res2.timeline])
    assert precise_frac > 0.3, precise_frac
    assert precise_frac > strict_frac, (precise_frac, strict_frac)


def test_multiapp_round_robin_balances_losses():
    svc = SERVICES["search-prefill"]
    jobs = [_job("phi4-mini-3.8b"), _job("olmoe-1b-7b"),
            _job("mamba2-780m")]
    for j in jobs:
        j.total_work = 900.0         # steady state dominates the transient
    res = simulate(svc, jobs, horizon_s=420, seed=4)
    median_p99 = float(np.median([p.p99 for p in res.timeline[5:]]))
    assert median_p99 <= svc.qos_target_s * 1.05
    assert res.qos_met_frac > 0.65
    losses = [j.quality_loss for j in jobs]
    assert max(losses) - min(losses) < 0.03, losses
    assert all(l <= 0.055 for l in losses)


def test_per_tenant_reclaim_budgets_in_sim():
    """Heterogeneous jobs reclaim up to their OWN chip-group budget — the
    old shared budget was sized from jobs[0] only, so a small lead job
    capped (or a big lead job overran) everyone else's."""
    svc = SERVICES["token-serve"]
    jobs = [_job("phi4-mini-3.8b"), _job("olmoe-1b-7b")]
    jobs[0].chip_groups = 2          # tiny lead job
    jobs[1].chip_groups = 24
    for j in jobs:
        j.total_work = 5000.0
    res = simulate(svc, jobs, horizon_s=120, seed=6, load_frac=0.95)
    assert res.max_reclaimed[0] <= 1, res.max_reclaimed
    assert res.max_reclaimed[1] > 1, \
        ("the big job's budget must not be capped by the small lead job",
         res.max_reclaimed)


@pytest.mark.parametrize("svc_name", list(SERVICES))
def test_interference_aware_at_least_matches_round_robin(svc_name):
    """On the heterogeneous contention-archetype mix, interference-aware
    victim selection meets QoS at least as often as round-robin with
    equal-or-lower mean quality loss (aggregate over fixed seeds), and
    stays within the paper's ~2.1% loss band."""
    svc = SERVICES[svc_name]
    agg = {}
    for arb in ("round_robin", "interference"):
        q, loss = [], []
        for seed in (4, 6):
            jobs = archetype_jobs()
            res = simulate(svc, jobs, horizon_s=300, seed=seed, arbiter=arb)
            q.append(res.qos_met_frac)
            loss.append(np.mean([j.quality_loss for j in jobs]))
        agg[arb] = (float(np.mean(q)), float(np.mean(loss)))
    (rr_q, rr_l), (ia_q, ia_l) = agg["round_robin"], agg["interference"]
    assert ia_q >= rr_q, agg
    assert ia_l <= rr_l, agg
    assert ia_l <= 0.021, agg


def test_decision_interval_sensitivity():
    """Coarse decision intervals leave QoS violations unresolved longer
    (paper Fig. 9): met-fraction degrades monotonically-ish with interval."""
    svc = SERVICES["token-serve"]
    fracs = {}
    for interval in [0.5, 1.0, 8.0]:
        job = _job("phi4-mini-3.8b")
        res = simulate(svc, [job], horizon_s=360, interval_s=interval,
                       seed=5)
        fracs[interval] = res.qos_met_frac
    assert fracs[0.5] >= fracs[8.0]
    assert fracs[1.0] >= fracs[8.0]


def test_interference_drops_with_approximation():
    svc = SERVICES["token-serve"]
    job = _job("phi4-mini-3.8b")
    i_precise = interference_of([job], svc)
    job.variant = len(job.table) - 1
    i_approx = interference_of([job], svc)
    assert i_approx < i_precise


def test_chip_reclamation_helps_when_approx_insufficient():
    svc = SERVICES["token-serve"]
    base = svc.p99(0.775, 0.3, 0)
    assert svc.p99(0.775, 0.3, 4) < base
