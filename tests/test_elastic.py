"""Deflation-grade elasticity (dist.elastic + engine/runtime wiring).

Covers the fault-injection substrate (deterministic schedules, the CLI
grammar, seeded chaos scripts), the surviving-mesh shrink policy (pinned
model axes, batch axes shrinking outermost-first, slot-affinity divisor
preference), the engine-side capacity actuations on a single device
(quota cuts on the page pool, transient collective-failure retries with
token parity, admission timeout + bounded backoff), the runtime's
capacity-pressure arm of the Fig. 3 hysteresis, corruption-tolerant
checkpoint restore, and — in an 8-device subprocess — the headline
guarantee: revoking 2 of 8 devices mid-decode and restoring them later
completes every request with tokens identical to the unfaulted run.
"""
import numpy as np
import pytest

from repro.dist import elastic
from repro.dist.elastic import CapacityEvent, FaultInjector


# ------------------------------------------------------------- injector --

def test_parse_grammar():
    inj = FaultInjector.parse(
        "revoke@20+5:2, restore@60, quota_cut@10:3, quota_restore@40, "
        "fail@15:2")
    evs = {(e.kind, e.step): e for e in inj._events}
    assert inj.pending() == 5
    r = evs[(elastic.REVOKE, 20)]
    assert r.count == 2 and r.deadline_steps == 5 and r.quanta == 0
    assert evs[(elastic.RESTORE, 60)].count == 0
    q = evs[(elastic.QUOTA_CUT, 10)]
    assert q.quanta == 3 and q.count == 0
    assert evs[(elastic.COLLECTIVE_FAILURE, 15)].count == 2
    with pytest.raises(AssertionError):
        FaultInjector.parse("explode@3")


def test_due_pops_in_step_then_schedule_order():
    inj = FaultInjector([CapacityEvent(elastic.RESTORE, 5),
                         CapacityEvent(elastic.REVOKE, 2, count=1),
                         CapacityEvent(elastic.QUOTA_CUT, 2, quanta=1)])
    assert inj.due(1) == []
    got = inj.due(4)
    assert [e.kind for e in got] == [elastic.REVOKE, elastic.QUOTA_CUT]
    assert inj.pending() == 1
    # a skipped-over step still delivers (driver loops may jump steps)
    assert [e.kind for e in inj.due(100)] == [elastic.RESTORE]
    assert inj.due(200) == [] and len(inj.delivered) == 3


def test_random_script_is_seed_deterministic():
    a = FaultInjector.random_script(n_rounds=3, max_step=50, n_devices=8,
                                    seed=7)
    b = FaultInjector.random_script(n_rounds=3, max_step=50, n_devices=8,
                                    seed=7)
    c = FaultInjector.random_script(n_rounds=3, max_step=50, n_devices=8,
                                    seed=8)
    assert a._events == b._events
    assert a._events != c._events
    kinds = [e.kind for e in a._events]
    assert kinds == [elastic.REVOKE, elastic.RESTORE] * 3
    steps = [e.step for e in a._events]
    assert steps == sorted(steps)
    for ev in a._events:
        if ev.kind == elastic.REVOKE:
            assert 1 <= ev.count <= 4


def test_capacity_event_validation():
    with pytest.raises(AssertionError):
        CapacityEvent("nonsense", 0)
    with pytest.raises(AssertionError):
        CapacityEvent(elastic.REVOKE, -1)


# ------------------------------------------------------- mesh shrinking --

def test_surviving_mesh_policy(subproc):
    out = subproc("""
import jax
from repro.dist import elastic
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))

# count-only revocation picks the highest-ordinal tail, skipping already-
# revoked ids, so survivors stay a contiguous prefix
assert elastic.pick_revoked(mesh, 2) == (6, 7)
assert elastic.pick_revoked(mesh, 1, already=(7,)) == (6,)
assert elastic.pick_revoked(mesh, 0) == ()

# nothing revoked: the mesh comes back unchanged
same, why = elastic.surviving_mesh(mesh, set())
assert same is mesh and why == "nothing revoked"

# 2 of 8 gone: model axis (2) is pinned, data shrinks 4 -> 3; with the
# slot-affinity preference (batch_slots=4) it lands on 2 (a divisor of 4
# costing <= half) using the survivor prefix
m, why = elastic.surviving_mesh(mesh, {6, 7}, prefer_divisor_of=4)
assert dict(m.shape) == {"data": 2, "model": 2}, m.shape
ids = sorted(int(d.id) for d in m.devices.ravel())
assert ids == [0, 1, 2, 3], ids
m2, _ = elastic.surviving_mesh(mesh, {6, 7})   # no preference: take all 6
assert dict(m2.shape) == {"data": 3, "model": 2}, m2.shape

# survivors cannot carry the pinned model axes -> (None, reason)
m3, why3 = elastic.surviving_mesh(mesh, set(range(1, 8)))
assert m3 is None and "pinned" in why3, (m3, why3)

# (pod, data) training mesh: pod shrinks FIRST (outermost batch axis)
tm = make_mesh((2, 4), ("pod", "data"))
m4, _ = elastic.surviving_mesh(tm, {5, 6, 7})
assert dict(m4.shape) == {"pod": 1, "data": 4}, m4.shape
print("MESH_POLICY_OK")
""", devices=8)
    assert "MESH_POLICY_OK" in out


def test_reshard_live_round_trip():
    import jax.numpy as jnp
    tree = {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones(4)}
    out = elastic.reshard_live(tree)
    assert np.allclose(np.asarray(out["w"]), np.arange(12.0).reshape(3, 4))
    staged = elastic.host_stage(tree)
    assert isinstance(staged["b"], np.ndarray)


# ------------------------------------------ engine capacity actuations --

def _setup(name="phi4-mini-3.8b"):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import api
    cfg = get_config(name + "-smoke")
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def _serve(eng, reqs):
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [r.out for r in reqs]


def test_collective_failure_retries_preserve_tokens():
    from repro.serve.engine import Request, ServeEngine
    cfg, params = _setup()
    rng = np.random.default_rng(2)
    prompts = [list(rng.integers(1, cfg.vocab_size, 6)) for _ in range(3)]

    def run(faults):
        eng = ServeEngine(cfg, batch_slots=2, max_len=32, params=params,
                          paged=True, page_size=4, prefill_chunk=4)
        reqs = [Request(i, prompt=p, max_new=5) for i, p in
                enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        while not eng.idle:
            if faults and eng.step_count == 3:
                eng.inject(CapacityEvent(elastic.COLLECTIVE_FAILURE, 0,
                                         count=2))
            eng.step()
        return eng, [r.out for r in reqs]

    ref_eng, ref = run(False)
    eng, got = run(True)
    assert got == ref, "a retried step must commit the same tokens"
    assert eng.stats["collective_retries"] == 2
    assert ref_eng.stats["collective_retries"] == 0
    assert any(e.get("kind") == elastic.COLLECTIVE_FAILURE
               for e in eng.elastic_log)


def test_quota_cut_is_separate_from_reclaim_ledger():
    from repro.serve.engine import Request, ServeEngine
    cfg, params = _setup()
    eng = ServeEngine(cfg, batch_slots=2, max_len=32, params=params,
                      paged=True, page_size=4, prefill_chunk=4)
    pool = eng.pool
    base_limit = pool.limit
    eng.inject(CapacityEvent(elastic.QUOTA_CUT, 0, quanta=1))
    eng.step()                      # events apply at the step boundary
    assert pool.capacity_cut == 1 and pool.reclaimed == 0
    assert pool.limit == base_limit - pool.quantum
    assert pool.stats["capacity_cut_events"] == 1
    # the arbiter's own ledger composes on top of the external floor
    pool.set_reclaimed(1)
    assert pool.limit == base_limit - 2 * pool.quantum
    pool.set_reclaimed(0)
    eng.inject(CapacityEvent(elastic.QUOTA_RESTORE, 0))
    eng.step()
    assert pool.capacity_cut == 0 and pool.limit == base_limit
    # the pool still serves traffic end to end after the round trip
    r = Request(0, prompt=[5, 9, 2, 7], max_new=4)
    assert _serve(eng, [r]) and r.done
    pool.assert_consistent()


def test_revoke_without_mesh_is_pressure_only():
    from repro.serve.engine import Request, ServeEngine
    cfg, params = _setup()
    eng = ServeEngine(cfg, batch_slots=2, max_len=32, params=params,
                      paged=True, page_size=4)
    eng.inject(CapacityEvent(elastic.REVOKE, 0, count=1))
    r = Request(0, prompt=[3, 1, 4], max_new=4)
    _serve(eng, [r])
    assert r.done
    assert any(e.get("ignored") == "no mesh" for e in eng.elastic_log)


def test_admission_timeout_rejects_structurally():
    import time
    from repro.serve.engine import Request, ServeEngine
    cfg, params = _setup()
    eng = ServeEngine(cfg, batch_slots=1, max_len=64, params=params,
                      prefill_chunk=4, admission_timeout_s=0.0005)
    first = Request(0, prompt=[3, 1, 4], max_new=12)
    eng.submit(first)
    eng.step()                              # first occupies the only slot
    late = Request(1, prompt=[2, 7, 1], max_new=4)
    eng.submit(late)
    time.sleep(0.002)
    eng.run()
    assert first.done and len(first.out) == 12
    assert late.rejected and not late.done and not late.out
    rej = late.rejection
    assert rej is not None and rej.uid == 1 and rej.waited_s > 0
    assert rej.queue_depth >= 1 and rej.step > 0
    assert eng.rejected == [late]
    assert eng.stats["admission_timeouts"] == 1
    # rejection is never silent drop: the driver loop's completion predicate
    # (done or rejected) must see every request resolved
    assert all(r.done or r.rejected for r in (first, late))


def test_blocked_admission_backs_off_then_recovers():
    from repro.serve.engine import Request, ServeEngine
    cfg, params = _setup()
    rng = np.random.default_rng(4)
    prompt = list(rng.integers(1, cfg.vocab_size, 6))

    ref_eng = ServeEngine(cfg, batch_slots=2, max_len=32, params=params,
                          paged=True, page_size=4, prefill_chunk=4)
    ref = Request(0, prompt=list(prompt), max_new=5)
    _serve(ref_eng, [ref])

    eng = ServeEngine(cfg, batch_slots=2, max_len=32, params=params,
                      paged=True, page_size=4, prefill_chunk=4)
    # external quota grab floors the budget to zero: admissions block
    eng.pool.set_capacity_cut(eng.pool.max_quanta + eng.pool.spec.usable)
    req = Request(0, prompt=list(prompt), max_new=5)
    eng.submit(req)
    for _ in range(12):
        eng.step()
    assert not req.done and req.uid in eng._backoff
    assert eng.stats["backoff_skips"] > 0, \
        "a blocked request must not hammer the allocator every step"
    blocked = eng.pool.stats["blocked_admissions"]
    assert 0 < blocked < 12, \
        (blocked, "backoff must skip most of the 12 retry opportunities")
    eng.inject(CapacityEvent(elastic.QUOTA_RESTORE, 0))
    eng.pool.set_capacity_cut(0)
    eng.run()
    assert req.done and req.out == ref.out
    assert req.uid not in eng._backoff


# ------------------------------------------------- runtime integration --

def test_capacity_pressure_forces_violation_arm():
    from repro.approx.knobs import PRECISE, ApproxKnobs
    from repro.core.controller import Action, ControllerConfig
    from repro.core.monitor import LatencyMonitor
    from repro.core.runtime import PliantRuntime
    from repro.core.variants import Variant, VariantTable
    table = VariantTable([
        Variant(PRECISE, 1.0, 0.0),
        Variant(ApproxKnobs(matmul_precision="int8"), 0.7, 0.003)])
    monitor = LatencyMonitor(qos_target_s=1e9, min_samples=4)
    rt = PliantRuntime(table, monitor,
                       ControllerConfig(decision_interval_s=0.0))
    monitor.record_many(np.full(8, 0.5))    # way under target: deep slack
    assert rt.maybe_decide() in (Action.HOLD, Action.STEP_PRECISE)

    rt.notify_capacity(CapacityEvent(elastic.REVOKE, 0, count=2))
    assert rt.capacity_pressure
    monitor.record_many(np.full(8, 0.5))    # still slack by latency alone
    act = rt.maybe_decide()
    assert act == Action.SET_MOST_APPROX and rt.active_variant == 1, \
        "outstanding capacity loss must enter the violation arm"
    assert rt.history[-1]["violated"] and not rt.history[-1]["slack"]
    assert rt.history[-1]["capacity"] == 1

    rt.notify_capacity(CapacityEvent(elastic.RESTORE, 0))
    assert not rt.capacity_pressure
    monitor.record_many(np.full(8, 0.5))
    rt.maybe_decide()                       # slack arm reachable again
    assert rt.active_variant == 0
    assert [e["kind"] for e in rt.capacity_log] == [elastic.REVOKE,
                                                    elastic.RESTORE]


def test_runtime_inject_fans_out_to_tenants():
    from repro.core.tenant import TrainTenant
    from repro.core.monitor import LatencyMonitor
    from repro.core.runtime import PliantRuntime
    from repro.core.variants import Variant, VariantTable
    from repro.approx.knobs import PRECISE
    table = VariantTable([Variant(PRECISE, 1.0, 0.0)])
    seen = []
    t = TrainTenant(table, name="train", elastic_fn=seen.append)
    rt = PliantRuntime(monitor=LatencyMonitor(1.0), tenants=[t])
    ev = CapacityEvent(elastic.REVOKE, 3, count=1)
    rt.inject(ev)
    assert seen == [ev] and rt.capacity_pressure


# --------------------------------------------------- checkpoint safety --

def test_restore_latest_skips_corrupt_checkpoints(tmp_path, capsys):
    from repro.ckpt import checkpoint as ckpt
    tree = {"w": np.arange(6.0).reshape(2, 3), "s": np.float32(3.0)}
    ckpt.save(tmp_path / "step_10", tree, 10)
    ckpt.save(tmp_path / "step_20",
              {"w": tree["w"] + 1, "s": np.float32(4.0)}, 20)
    ckpt.save(tmp_path / "step_30",
              {"w": tree["w"] + 2, "s": np.float32(5.0)}, 30)
    # newest torn mid-write (truncated npz), next-newest has a mangled
    # manifest — both classic kill-mid-copy shapes
    shard = tmp_path / "step_30" / "shard0.npz"
    shard.write_bytes(shard.read_bytes()[: 40])
    (tmp_path / "step_20" / "manifest.json").write_text("{not json")
    # plus a stale stage dir from a kill mid-save: swept at manager init
    stale = tmp_path / ".ckpt_tmp_dead"
    stale.mkdir()
    (stale / "junk").write_text("x")

    mgr = ckpt.CheckpointManager(tmp_path)
    assert not stale.exists()
    restored, step = mgr.restore_latest(tree)
    assert step == 10, "must fall back past BOTH corrupt checkpoints"
    assert np.allclose(restored["w"], tree["w"])
    assert len(mgr.skipped) == 2
    assert "step_30" in mgr.skipped[0] and "step_20" in mgr.skipped[1]
    err = capsys.readouterr().err
    assert err.count("WARNING: skipping corrupt/partial checkpoint") == 2

    # every checkpoint corrupt: (None, None), never a crash
    shard10 = tmp_path / "step_10" / "shard0.npz"
    shard10.write_bytes(b"\x00" * 10)
    mgr2 = ckpt.CheckpointManager(tmp_path)
    restored, step = mgr2.restore_latest(tree)
    assert restored is None and step is None and len(mgr2.skipped) == 3


# --------------------------------------------- 8-device chaos parity  --

def test_revoke_2_of_8_mid_decode_token_parity(subproc):
    """The headline robustness guarantee: a (4, 2) data x model engine that
    loses 2 devices mid-decode (with a grace deadline) and gets them back
    later completes EVERY request with tokens identical to the unfaulted
    run — zero drops, zero corruption — and stamps recovery metrics."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np, json
from repro.configs import get_config
from repro.dist import elastic
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.serve.engine import Request, ServeEngine

cfg = get_config("phi4-mini-3.8b-smoke")
params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
rng = np.random.default_rng(11)
prompts = [list(rng.integers(1, cfg.vocab_size, 7)) for _ in range(8)]

def run(script):
    mesh = make_mesh((4, 2), ("data", "model"))
    eng = ServeEngine(cfg, batch_slots=4, max_len=32, params=params,
                      mesh=mesh, paged=True, page_size=4, prefill_chunk=3)
    reqs = [Request(i, prompt=list(p), max_new=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    inj = elastic.FaultInjector.parse(script) if script else None
    steps = 0
    while not eng.idle and steps < 2000:
        if inj is not None:
            for ev in inj.due(steps):
                eng.inject(ev)
        eng.step()
        steps += 1
    assert eng.idle, "drained"
    return eng, reqs

ref_eng, ref = run("")
eng, got = run("revoke@4+2:2,restore@9")

assert all(r.done for r in got), [r.uid for r in got if not r.done]
assert not eng.rejected, "zero dropped requests"
assert [r.out for r in got] == [r.out for r in ref], "token parity"

rehomes = [e for e in eng.elastic_log if "mesh_shape" in e]
assert len(rehomes) == 2, eng.elastic_log       # shrink + grow
shrink, grow = rehomes
assert shrink["kind"] == "revoke" and shrink["revoked"] == [6, 7]
assert shrink["mesh_shape"] == {"data": 2, "model": 2}, shrink
assert shrink["pages_migrated"] > 0
assert shrink["recovery_steps"] is not None and shrink["recovery_steps"] >= 1
assert grow["kind"] == "restore" and grow["revoked"] == []
assert grow["mesh_shape"] == {"data": 4, "model": 2}, grow
notice = [e for e in eng.elastic_log if e.get("kind") == "revoke_notice"]
assert notice and notice[0]["deadline_step"] == notice[0]["step"] + 2
assert eng.stats["rehomes"] == 2 and eng.stats["capacity_events"] == 2
print("CHAOS_PARITY_OK " + json.dumps(dict(
    recovery_steps=shrink["recovery_steps"],
    pages=shrink["pages_migrated"])))
""", devices=8)
    assert "CHAOS_PARITY_OK" in out
