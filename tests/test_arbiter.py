"""Multi-tenant control plane: Tenant protocol, arbiter fairness/liveness
(per-tenant budgets, no starvation, eventual return to precise), and the
interference-aware victim selection math — property-based where the
invariant is over a space (hypothesis)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.arbiter import InterferenceAwareArbiter, RoundRobinArbiter
from repro.core.controller import Action, ControllerConfig
from repro.core.tenant import Tenant
from repro.core.variants import ResourcePressure


class StubTenant(Tenant):
    """Protocol-complete tenant with explicit ladders (no VariantTable):
    ``pressures[v]`` is the variant's roofline pressure, scaled by the
    share of quanta still held, like every real adapter."""

    def __init__(self, name, qlosses, pressures, budget=0, n_quanta=None):
        assert len(qlosses) == len(pressures)
        self.name = name
        self._ql = list(qlosses)
        self._pr = list(pressures)
        self.max_reclaim = budget
        self.n_quanta = n_quanta if n_quanta is not None else budget + 1
        self._variant = 0
        self._reclaimed = 0

    @property
    def n_variants(self):
        return len(self._ql)

    def quality_loss(self, variant=None):
        return self._ql[self.variant if variant is None else variant]

    def pressure(self, t=0.0, variant=None):
        v = self.variant if variant is None else variant
        return self._pr[v].scaled(self.share())


def P(h, i=0.1, f=0.3):
    return ResourcePressure(hbm=h, ici=i, flops=f)


def mk_tenants(n_apps=3, n_variants=4, budgets=(2, 5, 1)):
    """Heterogeneous ladder: tenant k's hbm pressure falls from 1/(k+1) at
    precise to a fifth of that at most-approximate."""
    out = []
    for k in range(n_apps):
        top = 1.0 / (k + 1)
        prs = [P(top * (1 - 0.8 * v / max(n_variants - 1, 1)))
               for v in range(n_variants)]
        qls = [0.01 * v * (k + 1) for v in range(n_variants)]
        out.append(StubTenant(f"t{k}", qls, prs, budget=budgets[k]))
    return out


def mk_arbiter(kind, tenants, cfg=None):
    cfg = cfg or ControllerConfig()
    if kind == "interference":
        return InterferenceAwareArbiter.from_tenants(
            tenants, cfg, sensitivity=P(0.6, 0.25, 0.05))
    return RoundRobinArbiter.from_tenants(tenants, cfg)


ARBS = ["round_robin", "interference"]


# -------------------------------------------------------- tenant protocol --

def test_tenant_state_and_actuation_bounds():
    t = StubTenant("x", [0.0, 0.01, 0.02], [P(1.0), P(0.6), P(0.2)],
                   budget=2, n_quanta=4)
    t.set_variant(2)
    assert t.variant == 2 and t.quality_loss() == 0.02
    t.reclaim()
    t.reclaim()
    t.reclaim()                      # clamped at budget
    assert t.reclaimed == 2
    assert t.share() == pytest.approx(0.5)
    # pressure scales with both the variant ladder and the held share
    assert t.pressure().hbm == pytest.approx(0.2 * 0.5)
    t.return_quanta(5)               # clamped at zero
    assert t.reclaimed == 0
    assert t.pressure(variant=0).hbm == pytest.approx(1.0)


# -------------------------------------------------- budgets and fairness --

@pytest.mark.parametrize("kind", ARBS)
def test_per_tenant_budgets_respected(kind):
    """Heterogeneous tenants get their OWN reclaim budgets — not a shared
    one sized from the first tenant (the old colocation bug)."""
    tenants = mk_tenants(budgets=(2, 5, 1))
    arb = mk_arbiter(kind, tenants)
    for _ in range(60):
        arb.tick(True, -0.5)
    assert [s.reclaimed for s in arb.states] == [2, 5, 1]
    assert [t.reclaimed for t in tenants] == [2, 5, 1]
    assert all(s.variant == s.most_approx for s in arb.states)


@pytest.mark.parametrize("kind", ARBS)
def test_sustained_slack_returns_all_to_precise(kind):
    """Liveness: after any violation prefix, sustained slack walks every
    tenant back to precise with all quanta returned, within the move
    budget (one move per interval)."""
    tenants = mk_tenants()
    arb = mk_arbiter(kind, tenants)
    for _ in range(40):
        arb.tick(True, -0.5)
    moves = sum(s.variant for s in arb.states) \
        + sum(s.reclaimed for s in arb.states)
    for _ in range(moves + 1):
        arb.tick(False, 0.5)
    assert all(s.variant == 0 and s.reclaimed == 0 for s in arb.states)
    assert all(t.variant == 0 and t.reclaimed == 0 for t in tenants)


@pytest.mark.parametrize("kind", ARBS)
def test_no_starvation_and_progress(kind):
    """Under sustained violation every tick makes progress while ANY move
    remains (no HOLD with moves available), and every tenant eventually
    reaches most-approximate — no tenant is passed over forever."""
    tenants = mk_tenants()
    arb = mk_arbiter(kind, tenants)
    total_moves = sum(t.n_variants > 1 for t in tenants) \
        + sum(t.max_reclaim for t in tenants)
    for k in range(total_moves):
        act, idx = arb.tick(True, -0.5)
        assert act != Action.HOLD and idx is not None, \
            f"held at move {k} with moves remaining"
    assert all(s.variant == s.most_approx for s in arb.states)
    assert all(s.reclaimed == arb.budget(i)
               for i, s in enumerate(arb.states))
    assert arb.tick(True, -0.5) == (Action.HOLD, None)


# ------------------------------------------- interference-aware selection --

def test_interference_jump_picks_contended_resource_victim():
    """HBM-sensitive service + one HBM-heavy and one ICI-heavy tenant: the
    jump victim is the HBM hog, not the cursor head."""
    hbm_hog = StubTenant("hbm", [0.0, 0.02], [P(1.0, 0.1), P(0.2, 0.1)])
    ici_hog = StubTenant("ici", [0.0, 0.02],
                         [ResourcePressure(0.2, 1.0, 0.3),
                          ResourcePressure(0.1, 0.2, 0.2)])
    arb = InterferenceAwareArbiter.from_tenants(
        [ici_hog, hbm_hog], ControllerConfig(),
        sensitivity=P(0.8, 0.1, 0.05))
    assert arb.contended_axis(0.0) == "hbm"
    act, idx = arb.tick(True, -0.5)
    assert (act, idx) == (Action.SET_MOST_APPROX, 1)
    # ICI-sensitive service attributes the other way
    arb2 = InterferenceAwareArbiter.from_tenants(
        [ici_hog, hbm_hog], ControllerConfig(),
        sensitivity=ResourcePressure(0.05, 0.9, 0.05))
    # (fresh states: the tenants were actuated above — reset them)
    ici_hog._variant = hbm_hog._variant = 0
    assert arb2.contended_axis(0.0) == "ici"
    act, idx = arb2.tick(True, -0.5)
    assert (act, idx) == (Action.SET_MOST_APPROX, 0)


def test_interference_step_back_buys_quality_cheapest_first():
    """Under slack, the first step toward precise goes to the tenant whose
    de-approximation adds the least contended pressure per quality gained
    (here: the ICI-heavy tenant, invisible on the contended HBM axis)."""
    hbm_hog = StubTenant("hbm", [0.0, 0.02], [P(1.0, 0.1), P(0.2, 0.1)])
    ici_hog = StubTenant("ici", [0.0, 0.02],
                         [ResourcePressure(0.15, 1.0, 0.3),
                          ResourcePressure(0.1, 0.2, 0.2)])
    arb = InterferenceAwareArbiter.from_tenants(
        [hbm_hog, ici_hog], ControllerConfig(),
        sensitivity=P(0.8, 0.1, 0.05))
    arb.tick(True, -0.5)
    arb.tick(True, -0.5)             # both jump to most-approximate
    act, idx = arb.tick(False, 0.5)
    assert (act, idx) == (Action.STEP_PRECISE, 1), (act, idx)


def test_interference_reclaim_prefers_per_quantum_relief():
    """Reclaim victimizes the tenant shedding the most contended pressure
    per quantum: same ladder, but one tenant spreads it over 4x the
    quanta."""
    a = StubTenant("wide", [0.0], [P(1.0)], budget=3, n_quanta=16)
    b = StubTenant("narrow", [0.0], [P(1.0)], budget=3, n_quanta=4)
    arb = InterferenceAwareArbiter.from_tenants(
        [a, b], ControllerConfig(), sensitivity=P(0.8, 0.1, 0.05))
    act, idx = arb.tick(True, -0.5)
    assert (act, idx) == (Action.RECLAIM_CHIPS, 1)


# ------------------------------------------------------------- runtime --

def _runtime(**kw):
    from repro.core.monitor import LatencyMonitor
    from repro.core.runtime import PliantRuntime
    monitor = LatencyMonitor(qos_target_s=1.0, min_samples=4)
    return PliantRuntime(monitor=monitor, **kw), monitor


def test_runtime_history_bounded():
    """Long-running control loops must not grow history without bound."""
    cfg = ControllerConfig(decision_interval_s=0.0, history_limit=32)
    tenants = [StubTenant("a", [0.0, 0.01], [P(1.0), P(0.5)])]
    rt, monitor = _runtime(cfg=cfg, tenants=tenants)
    for k in range(200):
        monitor.record_many(np.full(8, 2.0 if k % 2 else 0.1))
        rt.maybe_decide()
    assert len(rt.history) == 32
    assert rt.history.maxlen == 32


def test_runtime_multi_tenant_dispatch():
    """The runtime drives the arbiter over BOTH tenants: sustained
    violation approximates both and actuates each adapter; sustained slack
    walks both back (same ledger the sim uses)."""
    cfg = ControllerConfig(decision_interval_s=0.0)
    tenants = mk_tenants(2, 3, budgets=(1, 2))
    arb = mk_arbiter("interference", tenants, cfg)
    rt, monitor = _runtime(cfg=cfg, tenants=tenants, arbiter=arb)
    for _ in range(8):
        monitor.record_many(np.full(8, 5.0))     # violating
        rt.maybe_decide()
    assert all(t.variant == t.n_variants - 1 for t in tenants)
    assert [t.reclaimed for t in tenants] == [1, 2]
    for _ in range(16):
        monitor.record_many(np.full(8, 0.05))    # deep slack
        rt.maybe_decide()
    assert all(t.variant == 0 and t.reclaimed == 0 for t in tenants)
    victims = {h["victim"] for h in rt.history if h["victim"] is not None}
    assert victims == {0, 1}


def test_runtime_single_tenant_backcompat():
    """The legacy ``PliantRuntime(table, monitor)`` ctor still works: the
    table is wrapped in a zero-budget TrainTenant (no reshard actuator ->
    no phantom reclaim intervals) under a 1-tenant arbiter that IS the
    Fig. 3 policy."""
    from repro.approx.knobs import PRECISE, ApproxKnobs
    from repro.core.monitor import LatencyMonitor
    from repro.core.runtime import PliantRuntime
    from repro.core.variants import Variant, VariantTable
    table = VariantTable([
        Variant(PRECISE, 1.0, 0.0),
        Variant(ApproxKnobs(matmul_precision="int8"), 0.7, 0.003)])
    monitor = LatencyMonitor(qos_target_s=1.0, min_samples=4)
    rt = PliantRuntime(table, monitor,
                       ControllerConfig(decision_interval_s=0.0))
    assert rt.auto_tenant and rt.cfg.max_reclaim == 0
    monitor.record_many(np.full(8, 5.0))
    act = rt.maybe_decide()
    assert act == Action.SET_MOST_APPROX and rt.active_variant == 1
    # violating at most-approximate with no actuator: hold, never reclaim
    monitor.record_many(np.full(8, 5.0))
    assert rt.maybe_decide() == Action.HOLD and rt.reclaimed == 0
    # late-bound reclaimer restores the budget (serve engine construction
    # order) and the absolute count reaches the actuator
    seen = []
    rt.attach_reclaimer(seen.append, max_reclaim=2)
    assert rt.cfg.max_reclaim == 2
    monitor.record_many(np.full(8, 5.0))
    assert rt.maybe_decide() == Action.RECLAIM_CHIPS
    assert seen == [1] and rt.reclaimed == 1


# ------------------------------------------------------- property tests --

@settings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(st.booleans(),
                          st.floats(-1, 1, allow_nan=False)),
                min_size=1, max_size=80),
       st.integers(2, 4), st.integers(2, 5),
       st.lists(st.integers(0, 6), min_size=4, max_size=4),
       st.sampled_from(ARBS))
def test_arbiter_invariants(ticks, n_apps, n_variants, budgets, kind):
    """State always in bounds; per-tenant reclaim never exceeds THAT
    tenant's budget; at most one knob moves by one step (except the
    jump); violations never decrease any tenant's approximation."""
    tenants = mk_tenants(n_apps, n_variants, budgets[:n_apps])
    arb = mk_arbiter(kind, tenants)
    for violated, slack in ticks:
        before = [(s.variant, s.reclaimed) for s in arb.states]
        arb.tick(violated, slack)
        moved = 0
        for i, s in enumerate(arb.states):
            assert 0 <= s.variant < n_variants
            assert 0 <= s.reclaimed <= tenants[i].max_reclaim
            assert s.variant == tenants[i].variant
            assert s.reclaimed == tenants[i].reclaimed
            dv = abs(s.variant - before[i][0])
            dr = abs(s.reclaimed - before[i][1])
            assert dr <= 1 and (dv == 0 or dr == 0)
            moved += (dv > 0) + (dr > 0)
            if violated:
                assert s.variant >= before[i][0]
                assert s.reclaimed >= before[i][1]
        assert moved <= 1


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.booleans(),
                          st.floats(-1, 1, allow_nan=False)),
                min_size=0, max_size=40),
       st.integers(2, 4),
       st.lists(st.integers(0, 5), min_size=4, max_size=4),
       st.sampled_from(ARBS))
def test_arbiter_deapproximates_under_sustained_slack(prefix, n_apps,
                                                      budgets, kind):
    """From ANY reachable state, sustained slack returns every tenant to
    precise with all quanta given back — de-approximation cannot wedge."""
    tenants = mk_tenants(n_apps, 4, budgets[:n_apps])
    arb = mk_arbiter(kind, tenants)
    for violated, slack in prefix:
        arb.tick(violated, slack)
    worst = sum(s.variant + s.reclaimed for s in arb.states)
    for _ in range(worst + 1):
        arb.tick(False, 0.5)
    assert all(s.variant == 0 and s.reclaimed == 0 for s in arb.states)


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 4), st.integers(2, 5),
       st.lists(st.integers(0, 5), min_size=4, max_size=4),
       st.sampled_from(ARBS))
def test_arbiter_liveness_under_sustained_violation(n_apps, n_variants,
                                                    budgets, kind):
    """Sustained violation drains every available move (no starvation, no
    premature HOLD) in exactly jumps + sum(budgets) intervals."""
    tenants = mk_tenants(n_apps, n_variants, budgets[:n_apps])
    arb = mk_arbiter(kind, tenants)
    moves = n_apps + sum(t.max_reclaim for t in tenants)
    held = 0
    for _ in range(moves):
        act, _ = arb.tick(True, -0.5)
        held += act == Action.HOLD
    assert held == 0
    assert all(s.variant == s.most_approx for s in arb.states)
    assert all(t.reclaimed == t.max_reclaim for t in tenants)
