"""Colocation walkthrough (paper Fig. 4 in miniature): one latency-critical
service colocated with two approximate batch jobs; prints the per-interval
timeline — tail latency vs QoS, each job's active variant and yielded chips.

    PYTHONPATH=src python examples/colocation_sim.py
"""
from repro.configs import SHAPES, get_config
from repro.core.colocation import SERVICES, BatchJob, simulate
from repro.core.explorer import explore


def main():
    svc = SERVICES["token-serve"]
    jobs = []
    for arch in ["phi4-mini-3.8b", "olmoe-1b-7b"]:
        cfg = get_config(arch)
        table = explore(cfg, SHAPES["train_4k"])
        print(f"{arch}: {len(table)} variants on the Pareto frontier:")
        for v in table.variants:
            print(f"   {v.name:24s} rel_time={v.rel_time:.2f} "
                  f"quality_loss={v.quality_loss:.3f}")
        jobs.append(BatchJob(arch, table, total_work=120.0))

    res = simulate(svc, jobs, horizon_s=200, seed=3)
    print(f"\nQoS target {svc.qos_target_s*1e3:.1f} ms; "
          f"met {res.qos_met_frac:.0%} of intervals")
    print(f"{'t':>4} {'p99(ms)':>8} {'ok':>3} {'variants':>10} "
          f"{'yielded':>8}  action")
    for p in res.timeline[::4]:
        ok = "Y" if p.p99 <= svc.qos_target_s else "N"
        print(f"{p.t:4.0f} {p.p99*1e3:8.2f} {ok:>3} {str(p.variants):>10} "
              f"{str(p.reclaimed):>8}  {p.action}")
    for j in jobs:
        print(f"{j.name}: finished at {j.finished_at}s "
              f"(nominal {j.total_work:.0f}s), quality loss "
              f"{j.quality_loss:.2%}")


if __name__ == "__main__":
    main()
