"""Fault tolerance demo: train, checkpoint, simulate preemption, resume —
then restore the same checkpoint onto a different device topology (elastic).

    PYTHONPATH=src python examples/elastic_restart.py
"""
import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ck
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import api
from repro.train import optim, step as step_mod


def main():
    cfg = get_config("mamba2-780m-smoke")
    ckdir = pathlib.Path(tempfile.mkdtemp()) / "ckpt"
    data = SyntheticLM(DataConfig(cfg.vocab_size, 64, 8, seed=0))
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = optim.init_opt(params)
    step = jax.jit(step_mod.make_train_step(
        cfg, opt_cfg=optim.OptConfig(lr=3e-3, warmup=5, total_steps=40),
        remat="none"))
    mgr = ck.CheckpointManager(ckdir, period=10, keep=2)

    print("phase 1: train 25 steps, async-checkpoint every 10")
    for i in range(25):
        params, opt, m = step(params, opt,
                              {"tokens": jnp.asarray(data.batch(i))})
        mgr.maybe_save((params, opt), i + 1)
    mgr.wait()
    print(f"  latest checkpoint: step {ck.latest_step(ckdir)} "
          f"(simulating preemption here)")

    print("phase 2: fresh process restores and continues")
    params2 = api.init(cfg, jax.random.PRNGKey(99), jnp.float32)  # junk
    opt2 = optim.init_opt(params2)
    (params2, opt2), start = mgr.restore_latest((params2, opt2))
    print(f"  resumed from step {start}")
    for i in range(start, 40):
        params2, opt2, m = step(params2, opt2,
                                {"tokens": jnp.asarray(data.batch(i))})
    print(f"  final loss {float(m['loss']):.4f}")

    print("phase 3: elastic restore (same checkpoint, other mesh shapes) — "
          "see tests/test_dist.py::test_elastic_reshard_restore for the "
          "multi-device version")
    restored, s = mgr.restore_latest((params2, opt2))
    print(f"  re-restored step {s}; leaves intact: "
          f"{len(jax.tree.leaves(restored))}")


if __name__ == "__main__":
    main()
