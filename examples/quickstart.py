"""Quickstart: train a reduced-config model with the Pliant runtime enabled.

    PYTHONPATH=src python examples/quickstart.py [--arch <id>-smoke]

Every assigned architecture works (``--arch mamba2-780m-smoke``,
``--arch olmoe-1b-7b-smoke``, ...). The run prints the active approximate
variant and reclaimed chip-groups as a synthetic contention burst hits the
colocated interactive service mid-run.
"""
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] or
                            ["--arch", "phi4-mini-3.8b-smoke"]) + \
    ["--steps", "60", "--batch", "8", "--seq", "64", "--lr", "3e-3",
     "--pliant", "--decision-interval", "0.3"]

from repro.launch import train  # noqa: E402

if __name__ == "__main__":
    train.main()
