"""Serve a reduced model with continuous batching, precise vs approximate
(int8 KV cache) serving variants — the Pliant serving-side knobs.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.knobs import ApproxKnobs
from repro.configs import get_config
from repro.models import api
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_config("gemma2-27b-smoke")
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=4)) for _ in
               range(8)]
    for name, knobs in [("precise", ApproxKnobs()),
                        ("kv-int8", ApproxKnobs(kv_quant=True))]:
        eng = ServeEngine(cfg, batch_slots=4, max_len=64, params=params,
                          knobs=knobs)
        reqs = [Request(i, prompt=p, max_new=12)
                for i, p in enumerate(prompts)]
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r)
        eng.run()
        wall = time.perf_counter() - t0
        per_tok = np.mean(eng.step_latencies) * 1e3
        print(f"{name:8s}: {len(reqs)} requests x 12 tokens through 4 slots "
              f"in {wall:.2f}s ({per_tok:.1f} ms/engine-step)")
        print(f"  first outputs: {reqs[0].out}")


if __name__ == "__main__":
    main()
