"""Serve a reduced model with continuous batching under Pliant control:
chunked-prefill admission, explorer-derived serving variants, and a QoS
monitor hot-swapping the decode executable when the target is violated.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.controller import ControllerConfig
from repro.core.monitor import LatencyMonitor
from repro.core.runtime import PliantRuntime
from repro.launch.serve import serving_table
from repro.models import api
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_config("gemma2-27b-smoke")
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    table = serving_table(cfg, slots=4, max_len=64)
    print("serving variants (explorer grid):",
          [v.name for v in table.variants])
    rng = np.random.default_rng(0)
    # prompts longer than the admission chunk: prefill streams in 8-token
    # chunks into the batched caches instead of warming up via decode steps
    prompts = [list(rng.integers(1, cfg.vocab_size, size=20)) for _ in
               range(8)]
    for vi, v in enumerate(table.variants):
        eng = ServeEngine(cfg, batch_slots=4, max_len=64, params=params,
                          table=table, prefill_chunk=8)
        eng.set_variant(vi)
        reqs = [Request(i, prompt=p, max_new=12)
                for i, p in enumerate(prompts)]
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r)
        eng.run()
        wall = time.perf_counter() - t0
        per_tok = np.mean(eng.step_latencies) * 1e3
        print(f"{v.name:10s}: {len(reqs)} requests x 12 tokens through 4 "
              f"slots in {wall:.2f}s ({per_tok:.1f} ms/engine-step)")
        print(f"  first outputs: {reqs[0].out}")

    # close the loop: an impossible QoS target forces the controller to jump
    # to the most-approximate variant mid-run (watch the swap step index)
    monitor = LatencyMonitor(qos_target_s=1e-6, window=256, min_samples=8)
    runtime = PliantRuntime(table, monitor,
                            ControllerConfig(decision_interval_s=0.0))
    eng = ServeEngine(cfg, batch_slots=4, max_len=64, params=params,
                      runtime=runtime, prefill_chunk=8, temperature=0.7)
    reqs = [Request(i, prompt=p, max_new=12) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    print(f"pliant    : swaps={eng.swaps} -> "
          f"active={table.variants[eng.active_variant].name}")


if __name__ == "__main__":
    main()
