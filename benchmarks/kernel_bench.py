"""Kernel microbenchmarks: µs/call (CPU; Pallas interpret vs jnp reference)
and max abs error vs oracle. On TPU the same harness times the native path."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows, timed
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.ssd_scan import ssd_scan


def main(rows: Rows):
    # int8 matmul
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 256), jnp.float32)
    xq, xs = ref.quantize_rowwise(x)
    wq, ws = ref.quantize_rowwise(w, axis=0)
    t_ref, out_ref = timed(lambda: jax.block_until_ready(
        ref.int8_matmul_ref(xq, xs, wq, ws, jnp.float32)))
    t_k, out_k = timed(lambda: jax.block_until_ready(
        int8_matmul(xq, xs, wq, ws, out_dtype=jnp.float32, interpret=True,
                    bk=256)))
    err = float(jnp.max(jnp.abs(out_k - out_ref)))
    rows.add("kernel.int8_matmul.ref", t_ref * 1e6, "jnp oracle")
    rows.add("kernel.int8_matmul.pallas", t_k * 1e6,
             f"interpret;max_err={err:.2e}")

    # flash attention
    B, H, KVH, S, hd = 1, 4, 2, 512, 64
    q = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, hd)) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(3), (B, KVH, S, hd)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(4), (B, KVH, S, hd))
    t_ref, o_ref = timed(lambda: jax.block_until_ready(
        ref.mha_ref(q, k, v, causal=True)))
    t_k, o_k = timed(lambda: jax.block_until_ready(
        flash_attention(q, k, v, interpret=True)))
    err = float(jnp.max(jnp.abs(o_k - o_ref)))
    rows.add("kernel.flash_attention.ref", t_ref * 1e6, "jnp oracle")
    rows.add("kernel.flash_attention.pallas", t_k * 1e6,
             f"interpret;max_err={err:.2e}")
    t_p, _ = timed(lambda: jax.block_until_ready(
        flash_attention(q, k, v, interpret=True, kv_keep_stride=4)))
    rows.add("kernel.flash_attention.perforated", t_p * 1e6,
             "kv_keep_stride=4 (the attention-perforation knob)")

    # ssd scan
    B, S, Hh, P, N = 1, 256, 4, 64, 32
    x = jax.random.normal(jax.random.PRNGKey(5), (B, S, Hh, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(6), (B, S, Hh)))
    a = -jnp.exp(jax.random.uniform(jax.random.PRNGKey(7), (Hh,)))
    bb = jax.random.normal(jax.random.PRNGKey(8), (B, S, N)) * 0.5
    cc = jax.random.normal(jax.random.PRNGKey(9), (B, S, N)) * 0.5
    t_naive, o_naive = timed(lambda: jax.block_until_ready(
        ref.ssd_ref(x, dt, a, bb, cc)))
    t_chunk, o_chunk = timed(lambda: jax.block_until_ready(
        ref.ssd_chunked_ref(x, dt, a, bb, cc, chunk=64)))
    t_k, o_k = timed(lambda: jax.block_until_ready(
        ssd_scan(x, dt, a, bb, cc, chunk=64, interpret=True)))
    rows.add("kernel.ssd.naive_recurrence", t_naive * 1e6, "oracle")
    rows.add("kernel.ssd.chunked_jnp", t_chunk * 1e6,
             f"max_err={float(jnp.max(jnp.abs(o_chunk - o_naive))):.2e}")
    rows.add("kernel.ssd.pallas", t_k * 1e6,
             f"interpret;max_err={float(jnp.max(jnp.abs(o_k - o_naive))):.2e}")
    return rows
