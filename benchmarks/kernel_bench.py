"""Kernel microbenchmarks: µs/call (CPU; Pallas interpret vs jnp reference)
and max abs error vs oracle. On TPU the same harness times the native path.

The paged-decode section also accounts *bytes moved*: the gather path's HBM
traffic comes from the compiled executable's ``cost_analysis`` (it scales
with slots x max_len — the dense gather buffer), the fused kernel's from its
per-live-page cost model — the numbers behind the explorer's paged decode
pricing, persisted to ``BENCH_kernels.json``."""
from __future__ import annotations

import functools
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS_DIR, Rows, timed
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.paged_attention import decode_hbm_bytes, paged_attention
from repro.kernels.ssd_scan import ssd_scan


def main(rows: Rows):
    # int8 matmul
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 256), jnp.float32)
    xq, xs = ref.quantize_rowwise(x)
    wq, ws = ref.quantize_rowwise(w, axis=0)
    t_ref, out_ref = timed(lambda: jax.block_until_ready(
        ref.int8_matmul_ref(xq, xs, wq, ws, jnp.float32)))
    t_k, out_k = timed(lambda: jax.block_until_ready(
        int8_matmul(xq, xs, wq, ws, out_dtype=jnp.float32, interpret=True,
                    bk=256)))
    err = float(jnp.max(jnp.abs(out_k - out_ref)))
    rows.add("kernel.int8_matmul.ref", t_ref * 1e6, "jnp oracle")
    rows.add("kernel.int8_matmul.pallas", t_k * 1e6,
             f"interpret;max_err={err:.2e}")

    # flash attention
    B, H, KVH, S, hd = 1, 4, 2, 512, 64
    q = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, hd)) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(3), (B, KVH, S, hd)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(4), (B, KVH, S, hd))
    t_ref, o_ref = timed(lambda: jax.block_until_ready(
        ref.mha_ref(q, k, v, causal=True)))
    t_k, o_k = timed(lambda: jax.block_until_ready(
        flash_attention(q, k, v, interpret=True)))
    err = float(jnp.max(jnp.abs(o_k - o_ref)))
    rows.add("kernel.flash_attention.ref", t_ref * 1e6, "jnp oracle")
    rows.add("kernel.flash_attention.pallas", t_k * 1e6,
             f"interpret;max_err={err:.2e}")
    t_p, _ = timed(lambda: jax.block_until_ready(
        flash_attention(q, k, v, interpret=True, kv_keep_stride=4)))
    rows.add("kernel.flash_attention.perforated", t_p * 1e6,
             "kv_keep_stride=4 (the attention-perforation knob)")

    # ssd scan
    B, S, Hh, P, N = 1, 256, 4, 64, 32
    x = jax.random.normal(jax.random.PRNGKey(5), (B, S, Hh, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(6), (B, S, Hh)))
    a = -jnp.exp(jax.random.uniform(jax.random.PRNGKey(7), (Hh,)))
    bb = jax.random.normal(jax.random.PRNGKey(8), (B, S, N)) * 0.5
    cc = jax.random.normal(jax.random.PRNGKey(9), (B, S, N)) * 0.5
    t_naive, o_naive = timed(lambda: jax.block_until_ready(
        ref.ssd_ref(x, dt, a, bb, cc)))
    t_chunk, o_chunk = timed(lambda: jax.block_until_ready(
        ref.ssd_chunked_ref(x, dt, a, bb, cc, chunk=64)))
    t_k, o_k = timed(lambda: jax.block_until_ready(
        ssd_scan(x, dt, a, bb, cc, chunk=64, interpret=True)))
    rows.add("kernel.ssd.naive_recurrence", t_naive * 1e6, "oracle")
    rows.add("kernel.ssd.chunked_jnp", t_chunk * 1e6,
             f"max_err={float(jnp.max(jnp.abs(o_chunk - o_naive))):.2e}")
    rows.add("kernel.ssd.pallas", t_k * 1e6,
             f"interpret;max_err={float(jnp.max(jnp.abs(o_k - o_naive))):.2e}")

    paged_decode_rows(rows)
    sharded_decode_rows(rows)
    prefill_rows(rows)
    return rows


def _paged_case(live_per_slot: int, *, B=4, G=2, R=2, hd=32, P=8, M=8,
                n_pages=40, quantized=False, seed=0):
    """Random paged pool with ``live_per_slot`` mapped pages per slot (the
    last one partial); returns the fused-kernel argument tuple."""
    rng = np.random.default_rng(seed)
    if quantized:
        kp = rng.integers(-127, 128, (n_pages, P, G, hd)).astype(np.int8)
        vp = rng.integers(-127, 128, (n_pages, P, G, hd)).astype(np.int8)
    else:
        kp = (rng.normal(size=(n_pages, P, G, hd)) * 0.3).astype(np.float32)
        vp = rng.normal(size=(n_pages, P, G, hd)).astype(np.float32)
    block = np.zeros((B, M), np.int32)
    ppos = np.full((n_pages, P), -1, np.int32)
    pid = 1
    for b in range(B):
        for lp in range(live_per_slot):
            block[b, lp] = pid
            ppos[pid] = np.arange(lp * P, (lp + 1) * P)
            pid += 1
    position = np.full((B,), live_per_slot * P - P // 2 - 1, np.int32)
    q = (rng.normal(size=(B, G, R, hd)) * 0.3).astype(np.float32)
    return tuple(jnp.asarray(a) for a in (q, kp, vp, ppos, block, position))


def _gather_path(q, kp, vp, ppos, block, position, *, window=0, kv_scale=0.0):
    """The pre-kernel reference: materialize every block-table page into a
    dense (B, M*P) buffer, then one masked softmax (models.attention's
    ``_gather_pages`` path on raw arrays)."""
    from repro.models.attention import PagedKVCache, _gather_pages, _sdpa
    B, G, R, hd = q.shape
    cache = PagedKVCache(kp, vp, ppos, block)
    kk, vv, _, valid = _gather_pages(cache, block, position[:, None],
                                     window=window)
    dq = (lambda a: a.astype(q.dtype) * kv_scale) if kv_scale else \
        (lambda a: a.astype(q.dtype))
    o = _sdpa(q[:, None], dq(kk), dq(vv), mask=valid[:, None, None])
    return o[:, 0]


def _compiled_bytes(fn, *args) -> float:
    cost = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(cost, (list, tuple)):      # jax<=0.4.x drift
        cost = cost[0] if cost else {}
    return float(cost.get("bytes accessed", 0.0))


def paged_decode_rows(rows: Rows):
    """Fused paged-decode kernel vs the gather reference: µs/call + max err
    (fp32 / int8 KV / windowed) and the bytes-moved account showing fused
    HBM traffic scaling with LIVE pages while the gather path stays pinned
    at slots x max_len."""
    out = {}
    B, G, R, hd, P, M = 4, 2, 2, 32, 8, 8
    variants = [
        ("fp32", dict(), dict(quantized=False)),
        ("int8", dict(kv_scale=0.05), dict(quantized=True)),
        ("windowed", dict(window=16), dict(quantized=False)),
    ]
    for name, kw, mk in variants:
        q, kp, vp, ppos, block, position = _paged_case(4, B=B, G=G, R=R,
                                                       hd=hd, P=P, M=M, **mk)
        t_ref, o_ref = timed(lambda: jax.block_until_ready(
            _gather_path(q, kp, vp, ppos, block, position, **kw)))
        t_k, o_k = timed(lambda: jax.block_until_ready(
            paged_attention(q, kp, vp, ppos, block, position,
                            interpret=True, **kw)))
        err = float(jnp.max(jnp.abs(o_k - o_ref)))
        rows.add(f"kernel.paged_decode.{name}.gather", t_ref * 1e6,
                 "jnp gather reference")
        rows.add(f"kernel.paged_decode.{name}.fused", t_k * 1e6,
                 f"interpret;max_err={err:.2e}")
        out[name] = {"gather_us": t_ref * 1e6, "fused_us": t_k * 1e6,
                     "max_err": err}

    # bytes moved per decode step: gather traffic is live-page-INVARIANT
    # (the dense buffer is always B x M x P), fused traffic is live pages
    kv_bytes = 4
    for label, live in (("sparse", 2), ("dense", 8)):
        q, kp, vp, ppos, block, position = _paged_case(live, B=B, G=G, R=R,
                                                       hd=hd, P=P, M=M)
        gather_b = _compiled_bytes(_gather_path, q, kp, vp, ppos, block,
                                   position)
        fused_b = decode_hbm_bytes(B * live, P, G, hd, kv_bytes=kv_bytes,
                                   batch=B, n_heads=G * R, max_pages=M)
        out[f"bytes_{label}"] = {
            "live_pages": B * live,
            "gather_bytes": gather_b,      # cost_analysis of the gather exe
            "fused_bytes": fused_b,        # kernel cost model: O(live pages)
        }
        rows.add(f"kernel.paged_decode.bytes.{label}", fused_b,
                 f"live_pages={B * live};gather_bytes={gather_b:.0f}")
    (RESULTS_DIR / "BENCH_kernels.json").write_text(json.dumps(out, indent=1))
    return rows


# ----------------------------------------------------- sharded decode rows --
# The multi-device fast path: the fused kernel shard_map'd over the
# slot-affinity pool layout (models.attention._sharded_write_attend) vs the
# GSPMD dense-gather fallback, on 8 simulated devices. Runs in a subprocess
# because the device count is fixed at jax import.

_SHARD_B, _SHARD_G, _SHARD_R, _SHARD_HD = 8, 2, 2, 32
_SHARD_P, _SHARD_M, _SHARD_NSH = 8, 8, 4
_SHARD_PAGES = 80                       # 4 shards x 20 (null + 16 live + slack)


def _sharded_paged_case(live_per_slot: int, *, quantized=False, seed=0):
    """Slot-affinity layout: slot b's pages all come from the contiguous
    page range of shard ``b * n_shards // B``; each shard's first page is
    its local null sentinel (never mapped). Also returns the step's new K/V
    entries so the write+attend region can be benched as one unit."""
    B, G, hd = _SHARD_B, _SHARD_G, _SHARD_HD
    Pg, nsh, n_pages = _SHARD_P, _SHARD_NSH, _SHARD_PAGES
    chunk = n_pages // nsh
    rng = np.random.default_rng(seed)
    if quantized:
        kp = rng.integers(-127, 128, (n_pages, Pg, G, hd)).astype(np.int8)
        vp = rng.integers(-127, 128, (n_pages, Pg, G, hd)).astype(np.int8)
        knew = rng.integers(-127, 128, (B, G, hd)).astype(np.int8)
        vnew = rng.integers(-127, 128, (B, G, hd)).astype(np.int8)
    else:
        kp = (rng.normal(size=(n_pages, Pg, G, hd)) * 0.3).astype(np.float32)
        vp = rng.normal(size=(n_pages, Pg, G, hd)).astype(np.float32)
        knew = (rng.normal(size=(B, G, hd)) * 0.3).astype(np.float32)
        vnew = rng.normal(size=(B, G, hd)).astype(np.float32)
    block = np.zeros((B, _SHARD_M), np.int32)
    ppos = np.full((n_pages, Pg), -1, np.int32)
    nxt = [s * chunk + 1 for s in range(nsh)]
    for b in range(B):
        s = b * nsh // B
        for lp in range(live_per_slot):
            pid = nxt[s]
            nxt[s] += 1
            block[b, lp] = pid
            ppos[pid] = np.arange(lp * Pg, (lp + 1) * Pg)
    position = np.full((B,), live_per_slot * Pg - Pg // 2 - 1, np.int32)
    q = (rng.normal(size=(B, G, _SHARD_R, hd)) * 0.3).astype(np.float32)
    return q, kp, vp, ppos, block, position, knew, vnew


def _sharded_child():
    """Runs under XLA_FLAGS=--xla_force_host_platform_device_count=8; prints
    one SHARDED_JSON line the parent merges into BENCH_kernels.json."""
    from jax.sharding import NamedSharding, PartitionSpec as Psp

    from repro.dist.sharding import PagedDecodePlan
    from repro.kernels.paged_attention import sharded_decode_hbm_bytes
    from repro.launch.mesh import make_mesh
    from repro.models import attention as attn_mod

    assert jax.device_count() >= 8, jax.device_count()
    B, G, R, hd = _SHARD_B, _SHARD_G, _SHARD_R, _SHARD_HD
    Pg, M, nsh = _SHARD_P, _SHARD_M, _SHARD_NSH
    mesh = make_mesh((nsh, 2), ("data", "model"))
    plan = PagedDecodePlan("data", nsh, "model")
    active = jnp.ones((B,), bool)
    out = {"mesh": {"data": nsh, "model": 2}, "n_shards": nsh}

    def fused_fn(window, kv_scale):
        return jax.jit(functools.partial(
            attn_mod._sharded_write_attend, mesh=mesh, plan=plan,
            window=window, kv_scale=kv_scale, cap=0.0, interpret=True))

    def gather_jit(window, kv_scale):
        sh = lambda *s: NamedSharding(mesh, Psp(*s))
        pool = sh("data", None, "model", None)
        return jax.jit(
            functools.partial(_gather_path, window=window,
                              kv_scale=kv_scale),
            in_shardings=(sh("data", "model"), pool, pool,
                          sh("data", None), sh("data", None), sh("data")))

    def written_pool(case):
        # the gather comparator attends a pre-written pool: emulate the
        # step's dynamic write on host so both paths see identical caches
        q, kp, vp, ppos, block, position, knew, vnew = case
        kp2, vp2 = kp.copy(), vp.copy()
        for b in range(B):
            phys, off = block[b, position[b] // Pg], position[b] % Pg
            kp2[phys, off], vp2[phys, off] = knew[b], vnew[b]
        return kp2, vp2

    variants = [("fp32", dict(window=0, kv_scale=0.0), dict()),
                ("int8", dict(window=0, kv_scale=0.05),
                 dict(quantized=True)),
                ("windowed", dict(window=16, kv_scale=0.0), dict())]
    for name, kw, mk in variants:
        case = _sharded_paged_case(4, **mk)
        q, kp, vp, ppos, block, position, knew, vnew = case
        cache = attn_mod.PagedKVCache(*map(jnp.asarray,
                                           (kp, vp, ppos, block)))
        ff = fused_fn(**kw)
        args = tuple(map(jnp.asarray, (q, knew, vnew, position)))
        t_f, (o_f, _) = timed(lambda: jax.block_until_ready(
            ff(*args, active, cache)))
        kp2, vp2 = written_pool(case)
        gf = gather_jit(**kw)
        gargs = tuple(map(jnp.asarray, (q, kp2, vp2, ppos, block, position)))
        t_g, o_g = timed(lambda: jax.block_until_ready(gf(*gargs)))
        err = float(jnp.max(jnp.abs(o_f - o_g)))
        out[name] = {"gather_gspmd_us": t_g * 1e6,
                     "fused_sharded_us": t_f * 1e6, "max_err": err}

    # per-device bytes: fused traffic scales with live pages PER SHARD;
    # gather from the compiled GSPMD executable's cost_analysis
    for label, live in (("sparse", 2), ("dense", 8)):
        case = _sharded_paged_case(live)
        q, kp, vp, ppos, block, position, knew, vnew = case
        kp2, vp2 = written_pool(case)
        gf = gather_jit(0, 0.0)
        gargs = tuple(map(jnp.asarray, (q, kp2, vp2, ppos, block, position)))
        cost = gf.lower(*gargs).compile().cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        per_dev = sharded_decode_hbm_bytes(
            B * live, Pg, G, hd, n_shards=nsh, kv_bytes=4, batch=B,
            n_heads=G * R, max_pages=M)
        total = sharded_decode_hbm_bytes(
            B * live, Pg, G, hd, n_shards=1, kv_bytes=4, batch=B,
            n_heads=G * R, max_pages=M)
        out[f"bytes_{label}"] = {
            "live_pages": B * live,
            "live_per_shard": B * live // nsh,
            "gather_bytes": float(cost.get("bytes accessed", 0.0)),
            "fused_bytes_per_device": per_dev,
            "fused_bytes_total": total,
        }
    print("SHARDED_JSON:" + json.dumps(out))


def sharded_decode_rows(rows: Rows):
    """Spawn the 8-device child, merge its account under ``sharded`` in
    BENCH_kernels.json, and emit the comparison rows."""
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.kernel_bench", "--sharded-child"],
        capture_output=True, text=True, env=env)
    line = next((l for l in proc.stdout.splitlines()
                 if l.startswith("SHARDED_JSON:")), None)
    assert line is not None, (proc.stdout, proc.stderr[-2000:])
    sharded = json.loads(line[len("SHARDED_JSON:"):])
    path = RESULTS_DIR / "BENCH_kernels.json"
    out = json.loads(path.read_text())
    out["sharded"] = sharded
    path.write_text(json.dumps(out, indent=1))
    for name in ("fp32", "int8", "windowed"):
        s = sharded[name]
        rows.add(f"kernel.paged_decode.sharded.{name}.gather_gspmd",
                 s["gather_gspmd_us"], "GSPMD dense-gather fallback")
        rows.add(f"kernel.paged_decode.sharded.{name}.fused",
                 s["fused_sharded_us"],
                 f"shard_map x{sharded['n_shards']};interpret;"
                 f"max_err={s['max_err']:.2e}")
    for label in ("sparse", "dense"):
        b = sharded[f"bytes_{label}"]
        rows.add(f"kernel.paged_decode.sharded.bytes.{label}",
                 b["fused_bytes_per_device"],
                 f"live_per_shard={b['live_per_shard']};"
                 f"total={b['fused_bytes_total']:.0f};"
                 f"gather_bytes={b['gather_bytes']:.0f}")
    return rows


# ------------------------------------------------------- ring prefill rows --
# The sequence-parallel admission path: kernels.ring_attention shard_map'd
# over the prefill plan's ring vs the unsharded masked-softmax oracle, on 8
# simulated devices (subprocess — device count is fixed at jax import). The
# parent also stamps the 32k-target per-device cost model the explorer
# prices admission with; CI asserts per-device work scales ~1/n_shards.

_PRE_B, _PRE_C, _PRE_CACHE = 1, 64, 192
_PRE_G, _PRE_R, _PRE_HD = 2, 2, 32
_PRE_NSH = 4


def _prefill_case(quantized=False, seed=0):
    """One admission chunk (positions cache..cache+C) over its full visible
    context [cache; chunk] with a hole punched in the cache positions —
    exercises the -1-position masking the paged gather path produces."""
    B, C, G, R, hd = _PRE_B, _PRE_C, _PRE_G, _PRE_R, _PRE_HD
    L = _PRE_CACHE + C
    rng = np.random.default_rng(seed)
    if quantized:
        k = rng.integers(-127, 128, (B, L, G, hd)).astype(np.int8)
        v = rng.integers(-127, 128, (B, L, G, hd)).astype(np.int8)
    else:
        k = (rng.normal(size=(B, L, G, hd)) * 0.3).astype(np.float32)
        v = rng.normal(size=(B, L, G, hd)).astype(np.float32)
    q = (rng.normal(size=(B, C, G, R, hd)) * 0.3).astype(np.float32)
    q_pos = np.broadcast_to(np.arange(_PRE_CACHE, L, dtype=np.int32),
                            (B, C)).copy()
    kv_pos = np.broadcast_to(np.arange(L, dtype=np.int32), (B, L)).copy()
    kv_pos[:, 7:11] = -1                     # unmapped hole in the cache
    return tuple(jnp.asarray(a) for a in (q, k, v, q_pos, kv_pos))


def _prefill_ref(q, k, v, qp, kvp, *, window=0, cap=0.0, kv_scale=0.0):
    """Unsharded oracle: one masked softmax over the whole context with the
    same explicit-position mask the ring kernel applies."""
    dq = (lambda a: a.astype(jnp.float32) * kv_scale) if kv_scale else \
        (lambda a: a.astype(jnp.float32))
    k, v = dq(k), dq(v)
    hd = q.shape[-1]
    s = jnp.einsum("bcgrd,blgd->bgrcl", q.astype(jnp.float32),
                   k) * hd ** -0.5
    if cap:
        s = cap * jnp.tanh(s / cap)
    qe = qp[:, None, None, :, None]
    ke = kvp[:, None, None, None, :]
    mask = (ke >= 0) & (qe >= 0) & (ke <= qe)
    if window:
        mask &= ke > qe - window
    p = jax.nn.softmax(jnp.where(mask, s, -1e30), axis=-1)
    return jnp.einsum("bgrcl,blgd->bcgrd", p, v).astype(q.dtype)


def _prefill_child():
    """Runs under XLA_FLAGS=--xla_force_host_platform_device_count=8; prints
    one PREFILL_JSON line the parent merges into BENCH_kernels.json."""
    from repro.dist.sharding import PrefillPlan
    from repro.kernels.ring_attention import ring_chunk_attention
    from repro.launch.mesh import make_mesh

    assert jax.device_count() >= 8, jax.device_count()
    nsh = _PRE_NSH
    mesh = make_mesh((nsh, 2), ("data", "model"))
    plan = PrefillPlan("data", nsh, "model")
    out = {"mesh": {"data": nsh, "model": 2}, "n_shards": nsh,
           "chunk_len": _PRE_C, "kv_len": _PRE_CACHE + _PRE_C}
    variants = [("fp32", dict(), dict()),
                ("int8", dict(kv_scale=0.05), dict(quantized=True)),
                ("windowed", dict(window=32), dict())]
    for name, kw, mk in variants:
        q, k, v, qp, kvp = _prefill_case(**mk)
        rf = jax.jit(functools.partial(_prefill_ref, **kw))
        t_u, o_u = timed(lambda: jax.block_until_ready(rf(q, k, v, qp, kvp)))
        ring = jax.jit(functools.partial(ring_chunk_attention, mesh=mesh,
                                         plan=plan, interpret=True, **kw))
        t_r, o_r = timed(lambda: jax.block_until_ready(
            ring(q, k, v, qp, kvp)))
        err = float(jnp.max(jnp.abs(o_r - o_u)))
        out[name] = {"unsharded_us": t_u * 1e6, "ring_us": t_r * 1e6,
                     "max_err": err}
    print("PREFILL_JSON:" + json.dumps(out))


def prefill_rows(rows: Rows):
    """Spawn the 8-device ring-prefill child, merge its parity account plus
    the 32k-per-device cost model under ``prefill`` in BENCH_kernels.json."""
    from repro.kernels.ring_attention import (
        prefill_attn_flops, prefill_hbm_bytes, sharded_prefill_attn_flops,
        sharded_prefill_hbm_bytes)
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.kernel_bench", "--prefill-child"],
        capture_output=True, text=True, env=env)
    line = next((l for l in proc.stdout.splitlines()
                 if l.startswith("PREFILL_JSON:")), None)
    assert line is not None, (proc.stdout, proc.stderr[-2000:])
    prefill = json.loads(line[len("PREFILL_JSON:"):])
    # the 32k target shape the ISSUE's admission cell is sized for: 2k
    # chunks over a 32k context, 16 heads of 128 at 8-way GQA
    C32, L32, H32, G32, HD32 = 2048, 32768, 16, 8, 128
    nsh = prefill["n_shards"]
    prefill["flops_32k"] = {
        "total": prefill_attn_flops(C32, L32, H32, HD32),
        "per_device": sharded_prefill_attn_flops(C32, L32, H32, HD32,
                                                 n_shards=nsh),
    }
    for tag, kv_b in (("", 4), ("_int8", 1)):
        prefill[f"bytes_32k{tag}"] = {
            "total": prefill_hbm_bytes(C32, L32, G32, HD32, n_heads=H32,
                                       kv_bytes=kv_b),
            "per_device": sharded_prefill_hbm_bytes(
                C32, L32, G32, HD32, n_shards=nsh, n_heads=H32,
                kv_bytes=kv_b),
        }
    path = RESULTS_DIR / "BENCH_kernels.json"
    out = json.loads(path.read_text())
    out["prefill"] = prefill
    path.write_text(json.dumps(out, indent=1))
    for name in ("fp32", "int8", "windowed"):
        s = prefill[name]
        rows.add(f"kernel.ring_prefill.{name}.unsharded", s["unsharded_us"],
                 "jnp masked-softmax oracle")
        rows.add(f"kernel.ring_prefill.{name}.ring", s["ring_us"],
                 f"shard_map x{nsh};interpret;max_err={s['max_err']:.2e}")
    for key in ("flops_32k", "bytes_32k", "bytes_32k_int8"):
        w = prefill[key]
        rows.add(f"kernel.ring_prefill.{key}.per_device", w["per_device"],
                 f"total={w['total']:.3g};"
                 f"scaling=x{w['total'] / w['per_device']:.2f}/{nsh}")
    return rows


if __name__ == "__main__":
    if "--sharded-child" in sys.argv:
        _sharded_child()
    elif "--prefill-child" in sys.argv:
        _prefill_child()
