"""Kernel microbenchmarks: µs/call (CPU; Pallas interpret vs jnp reference)
and max abs error vs oracle. On TPU the same harness times the native path.

The paged-decode section also accounts *bytes moved*: the gather path's HBM
traffic comes from the compiled executable's ``cost_analysis`` (it scales
with slots x max_len — the dense gather buffer), the fused kernel's from its
per-live-page cost model — the numbers behind the explorer's paged decode
pricing, persisted to ``BENCH_kernels.json``."""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS_DIR, Rows, timed
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.paged_attention import decode_hbm_bytes, paged_attention
from repro.kernels.ssd_scan import ssd_scan


def main(rows: Rows):
    # int8 matmul
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 256), jnp.float32)
    xq, xs = ref.quantize_rowwise(x)
    wq, ws = ref.quantize_rowwise(w, axis=0)
    t_ref, out_ref = timed(lambda: jax.block_until_ready(
        ref.int8_matmul_ref(xq, xs, wq, ws, jnp.float32)))
    t_k, out_k = timed(lambda: jax.block_until_ready(
        int8_matmul(xq, xs, wq, ws, out_dtype=jnp.float32, interpret=True,
                    bk=256)))
    err = float(jnp.max(jnp.abs(out_k - out_ref)))
    rows.add("kernel.int8_matmul.ref", t_ref * 1e6, "jnp oracle")
    rows.add("kernel.int8_matmul.pallas", t_k * 1e6,
             f"interpret;max_err={err:.2e}")

    # flash attention
    B, H, KVH, S, hd = 1, 4, 2, 512, 64
    q = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, hd)) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(3), (B, KVH, S, hd)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(4), (B, KVH, S, hd))
    t_ref, o_ref = timed(lambda: jax.block_until_ready(
        ref.mha_ref(q, k, v, causal=True)))
    t_k, o_k = timed(lambda: jax.block_until_ready(
        flash_attention(q, k, v, interpret=True)))
    err = float(jnp.max(jnp.abs(o_k - o_ref)))
    rows.add("kernel.flash_attention.ref", t_ref * 1e6, "jnp oracle")
    rows.add("kernel.flash_attention.pallas", t_k * 1e6,
             f"interpret;max_err={err:.2e}")
    t_p, _ = timed(lambda: jax.block_until_ready(
        flash_attention(q, k, v, interpret=True, kv_keep_stride=4)))
    rows.add("kernel.flash_attention.perforated", t_p * 1e6,
             "kv_keep_stride=4 (the attention-perforation knob)")

    # ssd scan
    B, S, Hh, P, N = 1, 256, 4, 64, 32
    x = jax.random.normal(jax.random.PRNGKey(5), (B, S, Hh, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(6), (B, S, Hh)))
    a = -jnp.exp(jax.random.uniform(jax.random.PRNGKey(7), (Hh,)))
    bb = jax.random.normal(jax.random.PRNGKey(8), (B, S, N)) * 0.5
    cc = jax.random.normal(jax.random.PRNGKey(9), (B, S, N)) * 0.5
    t_naive, o_naive = timed(lambda: jax.block_until_ready(
        ref.ssd_ref(x, dt, a, bb, cc)))
    t_chunk, o_chunk = timed(lambda: jax.block_until_ready(
        ref.ssd_chunked_ref(x, dt, a, bb, cc, chunk=64)))
    t_k, o_k = timed(lambda: jax.block_until_ready(
        ssd_scan(x, dt, a, bb, cc, chunk=64, interpret=True)))
    rows.add("kernel.ssd.naive_recurrence", t_naive * 1e6, "oracle")
    rows.add("kernel.ssd.chunked_jnp", t_chunk * 1e6,
             f"max_err={float(jnp.max(jnp.abs(o_chunk - o_naive))):.2e}")
    rows.add("kernel.ssd.pallas", t_k * 1e6,
             f"interpret;max_err={float(jnp.max(jnp.abs(o_k - o_naive))):.2e}")

    paged_decode_rows(rows)
    return rows


def _paged_case(live_per_slot: int, *, B=4, G=2, R=2, hd=32, P=8, M=8,
                n_pages=40, quantized=False, seed=0):
    """Random paged pool with ``live_per_slot`` mapped pages per slot (the
    last one partial); returns the fused-kernel argument tuple."""
    rng = np.random.default_rng(seed)
    if quantized:
        kp = rng.integers(-127, 128, (n_pages, P, G, hd)).astype(np.int8)
        vp = rng.integers(-127, 128, (n_pages, P, G, hd)).astype(np.int8)
    else:
        kp = (rng.normal(size=(n_pages, P, G, hd)) * 0.3).astype(np.float32)
        vp = rng.normal(size=(n_pages, P, G, hd)).astype(np.float32)
    block = np.zeros((B, M), np.int32)
    ppos = np.full((n_pages, P), -1, np.int32)
    pid = 1
    for b in range(B):
        for lp in range(live_per_slot):
            block[b, lp] = pid
            ppos[pid] = np.arange(lp * P, (lp + 1) * P)
            pid += 1
    position = np.full((B,), live_per_slot * P - P // 2 - 1, np.int32)
    q = (rng.normal(size=(B, G, R, hd)) * 0.3).astype(np.float32)
    return tuple(jnp.asarray(a) for a in (q, kp, vp, ppos, block, position))


def _gather_path(q, kp, vp, ppos, block, position, *, window=0, kv_scale=0.0):
    """The pre-kernel reference: materialize every block-table page into a
    dense (B, M*P) buffer, then one masked softmax (models.attention's
    ``_gather_pages`` path on raw arrays)."""
    from repro.models.attention import PagedKVCache, _gather_pages, _sdpa
    B, G, R, hd = q.shape
    cache = PagedKVCache(kp, vp, ppos, block)
    kk, vv, _, valid = _gather_pages(cache, block, position[:, None],
                                     window=window)
    dq = (lambda a: a.astype(q.dtype) * kv_scale) if kv_scale else \
        (lambda a: a.astype(q.dtype))
    o = _sdpa(q[:, None], dq(kk), dq(vv), mask=valid[:, None, None])
    return o[:, 0]


def _compiled_bytes(fn, *args) -> float:
    cost = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(cost, (list, tuple)):      # jax<=0.4.x drift
        cost = cost[0] if cost else {}
    return float(cost.get("bytes accessed", 0.0))


def paged_decode_rows(rows: Rows):
    """Fused paged-decode kernel vs the gather reference: µs/call + max err
    (fp32 / int8 KV / windowed) and the bytes-moved account showing fused
    HBM traffic scaling with LIVE pages while the gather path stays pinned
    at slots x max_len."""
    out = {}
    B, G, R, hd, P, M = 4, 2, 2, 32, 8, 8
    variants = [
        ("fp32", dict(), dict(quantized=False)),
        ("int8", dict(kv_scale=0.05), dict(quantized=True)),
        ("windowed", dict(window=16), dict(quantized=False)),
    ]
    for name, kw, mk in variants:
        q, kp, vp, ppos, block, position = _paged_case(4, B=B, G=G, R=R,
                                                       hd=hd, P=P, M=M, **mk)
        t_ref, o_ref = timed(lambda: jax.block_until_ready(
            _gather_path(q, kp, vp, ppos, block, position, **kw)))
        t_k, o_k = timed(lambda: jax.block_until_ready(
            paged_attention(q, kp, vp, ppos, block, position,
                            interpret=True, **kw)))
        err = float(jnp.max(jnp.abs(o_k - o_ref)))
        rows.add(f"kernel.paged_decode.{name}.gather", t_ref * 1e6,
                 "jnp gather reference")
        rows.add(f"kernel.paged_decode.{name}.fused", t_k * 1e6,
                 f"interpret;max_err={err:.2e}")
        out[name] = {"gather_us": t_ref * 1e6, "fused_us": t_k * 1e6,
                     "max_err": err}

    # bytes moved per decode step: gather traffic is live-page-INVARIANT
    # (the dense buffer is always B x M x P), fused traffic is live pages
    kv_bytes = 4
    for label, live in (("sparse", 2), ("dense", 8)):
        q, kp, vp, ppos, block, position = _paged_case(live, B=B, G=G, R=R,
                                                       hd=hd, P=P, M=M)
        gather_b = _compiled_bytes(_gather_path, q, kp, vp, ppos, block,
                                   position)
        fused_b = decode_hbm_bytes(B * live, P, G, hd, kv_bytes=kv_bytes,
                                   batch=B, n_heads=G * R, max_pages=M)
        out[f"bytes_{label}"] = {
            "live_pages": B * live,
            "gather_bytes": gather_b,      # cost_analysis of the gather exe
            "fused_bytes": fused_b,        # kernel cost model: O(live pages)
        }
        rows.add(f"kernel.paged_decode.bytes.{label}", fused_b,
                 f"live_pages={B * live};gather_bytes={gather_b:.0f}")
    (RESULTS_DIR / "BENCH_kernels.json").write_text(json.dumps(out, indent=1))
    return rows
