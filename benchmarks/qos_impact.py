"""Paper Fig. 1 (even rows): impact of each selected approximate variant on
each interactive service's tail latency (static, per-variant — no control)."""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import RESULTS_DIR, Rows, job_for
from repro.core.colocation import SERVICES, interference_of


def main(rows: Rows):
    out = {}
    for arch in ["phi4-mini-3.8b", "olmoe-1b-7b", "mamba2-780m",
                 "gemma2-27b"]:
        job = job_for(arch)
        for svc_name, svc in SERVICES.items():
            mults = []
            for vi in range(len(job.table)):
                job.variant = vi
                interf = interference_of([job], svc)
                p99 = svc.p99(0.775, interf, 0)
                mults.append(p99 / svc.qos_target_s)
            out[f"{arch}|{svc_name}"] = {
                "variants": [v.name for v in job.table.variants],
                "p99_norm": mults,
            }
            # precise worst; approximation monotonically helps
            rows.add(f"fig1b.{arch}.{svc_name}", mults[0] * 100,
                     f"precise={mults[0]:.2f};most_approx={mults[-1]:.2f};"
                     f"monotone={all(mults[i] >= mults[i+1] - 1e-9 for i in range(len(mults)-1))}")
    (RESULTS_DIR / "qos_impact_fig1b.json").write_text(
        json.dumps(out, indent=1))
    return rows
