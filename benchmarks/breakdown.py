"""Paper Fig. 10: fraction of colocations where approximation ALONE meets QoS
vs needing 1 / 2 / 3+ reclaimed chip-groups, across 1-/2-/3-app mixes."""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import RESULTS_DIR, Rows, job_for
from repro.configs import ARCHS
from repro.core.colocation import SERVICES, simulate


def main(rows: Rows):
    archs = list(ARCHS)
    rng = np.random.default_rng(7)
    mixes = [(a,) for a in archs] + \
        [tuple(rng.choice(archs, 2, replace=False)) for _ in range(5)] + \
        [tuple(rng.choice(archs, 3, replace=False)) for _ in range(5)]
    out = {}
    for svc_name, svc in SERVICES.items():
        buckets = {"approx_only": 0, "1_group": 0, "2_groups": 0,
                   "3+_groups": 0}
        for mix in mixes:
            jobs = [job_for(a, total_work=300.0) for a in mix]
            res = simulate(svc, jobs, horizon_s=300, seed=hash(mix) % 2**31)
            worst = max(res.max_reclaimed)
            if worst == 0:
                buckets["approx_only"] += 1
            elif worst == 1:
                buckets["1_group"] += 1
            elif worst == 2:
                buckets["2_groups"] += 1
            else:
                buckets["3+_groups"] += 1
        total = sum(buckets.values())
        out[svc_name] = {k: v / total for k, v in buckets.items()}
        rows.add(f"fig10.{svc_name}", out[svc_name]["approx_only"] * 100,
                 ";".join(f"{k}={v:.2f}" for k, v in out[svc_name].items()))
    (RESULTS_DIR / "breakdown_fig10.json").write_text(
        json.dumps(out, indent=1))
    return rows
