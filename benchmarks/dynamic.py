"""Paper Fig. 4: Pliant's dynamic behavior — p99 / active variant / reclaimed
chips over time for selected (service x batch-job) colocations. Timelines go
to results/bench/dynamic_<svc>_<arch>.json."""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import RESULTS_DIR, Rows, job_for
from repro.core.colocation import SERVICES, simulate

PAIRS = [("token-serve", "phi4-mini-3.8b"),
         ("token-serve", "mamba2-780m"),
         ("search-prefill", "olmoe-1b-7b"),
         ("search-prefill", "gemma2-27b"),
         ("embed-api", "zamba2-2.7b"),
         ("embed-api", "whisper-large-v3")]


def main(rows: Rows):
    for svc_name, arch in PAIRS:
        svc = SERVICES[svc_name]
        job = job_for(arch, total_work=240.0)
        res = simulate(svc, [job], horizon_s=400, seed=21)
        tl = [{"t": p.t, "p99": p.p99, "variant": p.variants[0],
               "reclaimed": p.reclaimed[0], "action": p.action}
              for p in res.timeline]
        (RESULTS_DIR / f"dynamic_{svc_name}_{arch}.json").write_text(
            json.dumps({"qos": svc.qos_target_s, "timeline": tl}, indent=0))
        n_switch = sum(1 for p in res.timeline if "variant" not in p.action
                       and p.action != "hold")
        rows.add(f"fig4.{svc_name}.{arch}",
                 res.exec_time() * 1e6 / max(len(res.timeline), 1),
                 f"met={res.qos_met_frac:.2f};max_reclaim="
                 f"{res.max_reclaimed[0]};actions={n_switch};"
                 f"loss={job.quality_loss:.3f}")
    return rows
