"""Benchmark driver — one module per paper table/figure plus kernels and the
roofline table. Prints ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import sys
import time


def main() -> None:
    import types

    from benchmarks import (aggregate, breakdown, common, dynamic,
                            interval_sweep, kernel_bench, load_sweep,
                            multiapp, pareto, qos_impact, roofline_table,
                            serve_qos)
    rows = common.Rows()
    t0 = time.time()
    only = sys.argv[1] if len(sys.argv) > 1 else None
    colocation = types.SimpleNamespace(main=multiapp.colocation_main)
    mods = [("kernels", kernel_bench), ("fig1", pareto),
            ("fig1b", qos_impact), ("fig4", dynamic), ("fig5", aggregate),
            ("fig7", multiapp), ("colocation", colocation),
            ("fig8", load_sweep), ("fig9", interval_sweep),
            ("fig10", breakdown), ("serve", serve_qos),
            ("roofline", roofline_table)]
    for name, mod in mods:
        if only and only != name:
            continue
        t = time.time()
        mod.main(rows)
        print(f"# {name} done in {time.time()-t:.1f}s", file=sys.stderr)
    print("name,us_per_call,derived")
    rows.emit()
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
