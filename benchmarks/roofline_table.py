"""§Roofline: the three-term roofline per (arch x shape) from the dry-run
artifacts — compute/memory/collective seconds, dominant term, MODEL_FLOPS /
HLO_FLOPs ratio, roofline fraction, and fits-in-HBM check."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import DRYRUN_DIR, RESULTS_DIR, Rows

HBM_PER_CHIP = 16 * 2 ** 30     # v5e


def table(mesh: str = "pod", variant: str = "precise"):
    out = []
    for p in sorted(DRYRUN_DIR.glob(f"*__{mesh}__{variant}*.json")):
        art = json.loads(p.read_text())
        if art.get("skipped"):
            continue
        out.append(art)
    return out


def fmt_row(a):
    return (f"{a['arch']:22s} {a['shape']:12s} {a.get('variant','precise'):10s} "
            f"c={a['compute_s']:8.3f}s m={a['memory_s']:8.3f}s "
            f"w={a['collective_s']:8.3f}s dom={a['dominant']:10s} "
            f"useful={a['useful_ratio']:5.3f} frac={a['roofline_fraction']:6.3f} "
            f"peak={a['peak_bytes_est']/2**30:6.2f}GiB "
            f"fits={'Y' if a['peak_bytes_est'] <= HBM_PER_CHIP else 'N'}")


def main(rows: Rows):
    arts = table("pod")
    print("#", "-" * 118)
    print("# ROOFLINE TABLE (single-pod 16x16, precise baseline)")
    for a in arts:
        print("#", fmt_row(a))
    from repro.configs import all_cells
    for arch, shape, ok, reason in all_cells():
        if not ok:
            print(f"# {arch.name:22s} {shape.name:12s} SKIPPED: {reason}")
    print("#", "-" * 118)
    from repro import roofline as rl
    from repro.configs import SHAPES, get_config
    for a in arts:
        bound = max(a["compute_s"], a["memory_s"], a["collective_s"])
        extra = ""
        if SHAPES[a["shape"]].kind == "decode":
            # HLO memory term counts softmax-chain traffic that the Pallas
            # flash-decode kernel keeps in VMEM; report the kernel-adjusted
            # lower bound too (weights+cache once per token step)
            adj = rl.decode_min_bytes(get_config(a["arch"]),
                                      SHAPES[a["shape"]], a["n_chips"],
                                      kv_quant="kvq" in a.get("variant", ""))
            extra = f";adj_mem_s={adj / rl.HBM_BW:.4f}"
        rows.add(f"roofline.{a['arch']}.{a['shape']}", bound * 1e6,
                 f"dom={a['dominant']};frac={a['roofline_fraction']:.3f};"
                 f"useful={a['useful_ratio']:.3f};"
                 f"fits={a['peak_bytes_est'] <= HBM_PER_CHIP}" + extra)
    summary = {
        "n_cells": len(arts),
        "dominated_by": {k: sum(1 for a in arts if a["dominant"] == k)
                         for k in ("compute", "memory", "collective")},
        "all_fit": all(a["peak_bytes_est"] <= HBM_PER_CHIP for a in arts),
    }
    (RESULTS_DIR / "roofline_summary.json").write_text(
        json.dumps(summary, indent=1))
    rows.add("roofline.cells_reported", summary["n_cells"],
             json.dumps(summary["dominated_by"]))
    return rows
