"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import pathlib
import time

DRYRUN_DIR = pathlib.Path(__file__).resolve().parent.parent / "results" / \
    "dryrun"
RESULTS_DIR = pathlib.Path(__file__).resolve().parent.parent / "results" / \
    "bench"
RESULTS_DIR.mkdir(parents=True, exist_ok=True)


def load_artifact(arch: str, shape: str = "train_4k", mesh: str = "pod",
                  variant: str = "precise"):
    p = DRYRUN_DIR / f"{arch}__{shape}__{mesh}__{variant}.json"
    if p.exists():
        return json.loads(p.read_text())
    return None


def job_for(arch: str, shape_name: str = "train_4k", total_work: float = 300.0,
            serving: bool = False):
    """BatchJob with a variant table anchored on the dry-run artifact."""
    from repro.configs import SHAPES, get_config
    from repro.core.colocation import BatchJob
    from repro.core.explorer import explore
    cfg = get_config(arch)
    art = load_artifact(arch, shape_name)
    table = explore(cfg, SHAPES[shape_name], serving=serving,
                    baseline_art=art)
    import numpy as _np
    rng = _np.random.default_rng(abs(hash(arch)) % 2**31)
    return BatchJob(name=arch, table=table, total_work=total_work,
                    phase_offset=float(rng.uniform(0, 2 * _np.pi)),
                    phase_period=float(rng.uniform(50, 120)))


class Rows:
    def __init__(self):
        self.rows = []

    def add(self, name: str, us_per_call: float, derived: str = ""):
        self.rows.append((name, us_per_call, derived))

    def emit(self):
        for name, us, derived in self.rows:
            print(f"{name},{us:.2f},{derived}")


def timed(fn, *args, n=3, **kw):
    fn(*args, **kw)                     # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    return (time.perf_counter() - t0) / n, out
