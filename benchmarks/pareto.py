"""Paper Fig. 1 (odd rows): MEASURED approximation design-space exploration.

For reduced configs of representative archs, run every candidate variant for
a short real training run on CPU, recording (step time, quality loss vs
precise); then Pareto-prune exactly as the explorer does. Writes the scatter
to results/bench/pareto_<arch>.json and prints the selected frontier.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS_DIR, Rows
from repro.approx.knobs import ApproxKnobs, PRECISE
from repro.configs import get_config
from repro.core.explorer import pareto_front
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import api
from repro.train import optim, step as step_mod

ARCHS = ["phi4-mini-3.8b", "olmoe-1b-7b", "mamba2-780m"]
STEPS = 30
B, S = 8, 64


def measure_variant(cfg, knobs, data, key=0):
    params = api.init(cfg, jax.random.PRNGKey(key), jnp.float32)
    opt = optim.init_opt(params)
    step = jax.jit(step_mod.make_train_step(
        cfg, knobs, opt_cfg=optim.OptConfig(lr=3e-3, warmup=5,
                                            total_steps=STEPS),
        remat="none"))
    batch0 = {"tokens": jnp.asarray(data.batch(0))}
    step(params, opt, batch0)           # compile
    t0 = time.perf_counter()
    losses = []
    for i in range(STEPS):
        batch = {"tokens": jnp.asarray(data.batch(i))}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    wall = time.perf_counter() - t0
    return wall / STEPS, float(np.mean(losses[-8:]))


def grid_for(cfg):
    cands = [PRECISE,
             ApproxKnobs(matmul_precision="int8"),
             ApproxKnobs(token_drop=0.25),
             ApproxKnobs(token_drop=0.5),
             ApproxKnobs(layer_skip=0.5),
             ApproxKnobs(matmul_precision="int8", token_drop=0.25)]
    if any(k in ("attn", "local") for k in cfg.kinds()):
        cands.append(ApproxKnobs(kv_keep_stride=4))
    if cfg.moe is not None:
        cands += [ApproxKnobs(topk_override=1),
                  ApproxKnobs(topk_override=1, matmul_precision="int8")]
    return cands


def main(rows: Rows):
    for arch in ARCHS:
        cfg = get_config(arch + "-smoke")
        data = SyntheticLM(DataConfig(cfg.vocab_size, S, B, seed=0))
        t_precise, loss_precise = measure_variant(cfg, PRECISE, data)
        points = []
        for knobs in grid_for(cfg):
            t, loss = measure_variant(cfg, knobs, data)
            inacc = max(0.0, (loss - loss_precise) / loss_precise)
            points.append({"knobs": knobs.describe(),
                           "rel_time": t / t_precise,
                           "inaccuracy": inacc})
        front = pareto_front([(p["inaccuracy"], p["rel_time"])
                              for p in points])
        sel = [points[i]["knobs"] for i in front
               if points[i]["inaccuracy"] <= 0.05]
        out = {"arch": arch, "points": points, "selected": sel,
               "precise_s_per_step": t_precise}
        (RESULTS_DIR / f"pareto_{arch}.json").write_text(json.dumps(out,
                                                                    indent=1))
        rows.add(f"fig1.pareto.{arch}", t_precise * 1e6,
                 f"variants={len(points)};frontier={len(sel)}")
    return rows
