"""Paper Fig. 5: Precise vs Pliant across ALL 10 archs x 3 interactive
services — tail latency (bars), batch execution time (markers), inaccuracy
(labels). The headline reproduction table."""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import RESULTS_DIR, Rows, job_for
from repro.configs import ARCHS
from repro.core.colocation import PAPER_ANALOGUE, SERVICES, simulate


def main(rows: Rows):
    table = {}
    for svc_name, svc in SERVICES.items():
        for arch in ARCHS:
            job_p = job_for(arch)
            res_p = simulate(svc, [job_p], precise_only=True, horizon_s=90,
                             seed=11)
            p99_precise = float(np.median([p.p99 for p in res_p.timeline]))

            job = job_for(arch)
            res = simulate(svc, [job], horizon_s=500, seed=12)
            p99_pliant = float(np.percentile(
                [p.p99 for p in res.timeline[5:]], 90))
            nominal = job.total_work
            table[f"{svc_name}|{arch}"] = {
                "precise_mult": p99_precise / svc.qos_target_s,
                "pliant_mult": p99_pliant / svc.qos_target_s,
                "exec_time_ratio": res.exec_time() / nominal,
                "inaccuracy": job.quality_loss,
                "qos_met_frac": res.qos_met_frac,
            }
    (RESULTS_DIR / "aggregate_fig5.json").write_text(
        json.dumps(table, indent=1))
    # paper-claim summary
    inacc = [v["inaccuracy"] for v in table.values()]
    met = [v["qos_met_frac"] for v in table.values()]
    viol = [v["precise_mult"] for v in table.values()]
    for svc_name in SERVICES:
        sub = [v for k, v in table.items() if k.startswith(svc_name)]
        rows.add(f"fig5.{svc_name}.precise_viol_x",
                 float(np.median([v["precise_mult"] for v in sub])) * 100,
                 f"range={min(v['precise_mult'] for v in sub):.2f}-"
                 f"{max(v['precise_mult'] for v in sub):.2f} "
                 f"(paper {PAPER_ANALOGUE[svc_name]})")
    rows.add("fig5.mean_inaccuracy_pct", float(np.mean(inacc)) * 1e4,
             f"mean={np.mean(inacc):.4f} max={max(inacc):.4f} "
             f"paper=0.021/0.054")
    rows.add("fig5.qos_met_frac", float(np.mean(met)) * 100,
             f"mean={np.mean(met):.3f} min={min(met):.3f}")
    exec_ok = np.mean([v["exec_time_ratio"] <= 1.25 for v in table.values()])
    rows.add("fig5.exec_time_within_125pct", exec_ok * 100,
             "paper: all but water_spatial keep nominal time")
    return rows
