"""Paper Fig. 8: sensitivity to input load (40%..100% of saturation)."""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import RESULTS_DIR, Rows, job_for
from repro.core.colocation import SERVICES, simulate

LOADS = [0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0]


def main(rows: Rows):
    out = {}
    for svc_name, svc in SERVICES.items():
        arch = "phi4-mini-3.8b"
        for load in LOADS:
            job = job_for(arch, total_work=240.0)
            res = simulate(svc, [job], horizon_s=360, load_frac=load,
                           seed=31)
            tail = float(np.percentile([p.p99 for p in res.timeline[5:]],
                                       90))
            precise_frac = float(np.mean(
                [p.variants[0] == 0 for p in res.timeline]))
            out[f"{svc_name}|{load:.1f}"] = {
                "p99_norm": tail / svc.qos_target_s,
                "met": res.qos_met_frac,
                "exec_ratio": res.exec_time() / job.total_work,
                "precise_frac": precise_frac,
                "inaccuracy": job.quality_loss,
            }
        met_by_load = {l: out[f"{svc_name}|{l:.1f}"]["met"] for l in LOADS}
        low_ok = met_by_load[0.4] > 0.9 and met_by_load[0.5] > 0.9
        rows.add(f"fig8.{svc_name}", out[f"{svc_name}|0.8"]["p99_norm"] * 100,
                 f"met@0.4={met_by_load[0.4]:.2f};met@0.8="
                 f"{met_by_load[0.8]:.2f};met@1.0={met_by_load[1.0]:.2f};"
                 f"low_load_ok={low_ok}")
    (RESULTS_DIR / "load_fig8.json").write_text(json.dumps(out, indent=1))
    return rows
