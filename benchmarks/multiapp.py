"""Paper Fig. 6 + 7: multi-application colocations. Sampled 2- and 3-way
mixes of the 10 archs per service; violin stats (min/mean/max) of normalized
tail latency, execution time, and inaccuracy; round-robin balance check.

Also the arbiter comparison (``colocation_main`` -> BENCH_colocation.json):
round-robin vs interference-aware victim selection on a steady-state
heterogeneous colocation across the three calibrated service profiles."""
from __future__ import annotations

import itertools
import json

import numpy as np

from benchmarks.common import RESULTS_DIR, Rows, job_for
from repro.configs import ARCHS
from repro.core.colocation import SERVICES, archetype_jobs, simulate


def main(rows: Rows):
    archs = list(ARCHS)
    rng = np.random.default_rng(0)
    mixes2 = [tuple(rng.choice(archs, 2, replace=False)) for _ in range(6)]
    mixes3 = [tuple(rng.choice(archs, 3, replace=False)) for _ in range(6)]
    out = {}
    for svc_name, svc in SERVICES.items():
        for n_apps, mixes in [(1, [(a,) for a in archs[:6]]),
                              (2, mixes2), (3, mixes3)]:
            p99n, execn, inacc, spreads = [], [], [], []
            for mix in mixes:
                jobs = [job_for(a, total_work=500.0) for a in mix]
                res = simulate(svc, jobs, horizon_s=420,
                               seed=hash(mix) % 2**31)
                p99n += [p.p99 / svc.qos_target_s for p in res.timeline[5:]]
                execn += [res.exec_time(j) / jobs[j].total_work
                          for j in range(len(jobs))]
                losses = [j.quality_loss for j in jobs]
                inacc += losses
                if len(losses) > 1:
                    spreads.append(max(losses) - min(losses))
            key = f"{svc_name}|{n_apps}apps"
            out[key] = {
                "p99_norm": [float(np.min(p99n)), float(np.mean(p99n)),
                             float(np.max(p99n))],
                "exec_norm": [float(np.min(execn)), float(np.mean(execn)),
                              float(np.max(execn))],
                "inaccuracy": [float(np.min(inacc)), float(np.mean(inacc)),
                               float(np.max(inacc))],
                "loss_spread_max": float(max(spreads)) if spreads else 0.0,
            }
            rows.add(f"fig7.{svc_name}.{n_apps}apps",
                     out[key]["p99_norm"][1] * 100,
                     f"inacc_mean={out[key]['inaccuracy'][1]:.4f};"
                     f"spread={out[key]['loss_spread_max']:.4f}")
    (RESULTS_DIR / "multiapp_fig7.json").write_text(json.dumps(out, indent=1))
    return rows


# ------------------------------------------------ arbiter comparison -------

# fixed seeds; the CI gate asserts on the PER-SERVICE AGGREGATE over them
COLO_SEEDS = (1, 2, 4, 5, 6, 12)


def compare_arbiters(seeds=COLO_SEEDS, horizon_s: float = 300.0):
    """{service: {arbiter: {qos_met_frac, mean_quality_loss, work_done}}}."""
    out = {}
    for svc_name, svc in SERVICES.items():
        per = {}
        for arb in ("round_robin", "interference"):
            q, loss, work = [], [], []
            for s in seeds:
                jobs = archetype_jobs()
                res = simulate(svc, jobs, horizon_s=horizon_s, seed=s,
                               arbiter=arb)
                q.append(res.qos_met_frac)
                loss.append(float(np.mean([j.quality_loss for j in jobs])))
                work.append(float(np.mean([j.work_done for j in jobs])))
            per[arb] = {
                "qos_met_frac": float(np.mean(q)),
                "mean_quality_loss": float(np.mean(loss)),
                "work_done": float(np.mean(work)),
            }
        out[svc_name] = per
    return out


def colocation_main(rows: Rows):
    """BENCH_colocation.json: interference-aware vs round-robin. CI asserts
    the interference-aware arbiter meets QoS at least as often with equal-
    or-lower mean quality loss, within the paper's ~2.1% loss band."""
    out = compare_arbiters()
    for svc_name, per in out.items():
        rr, ia = per["round_robin"], per["interference"]
        rows.add(f"colocation.{svc_name}", ia["qos_met_frac"] * 100,
                 f"rr_qos={rr['qos_met_frac']:.4f};"
                 f"ia_loss={ia['mean_quality_loss']:.5f};"
                 f"rr_loss={rr['mean_quality_loss']:.5f}")
    (RESULTS_DIR / "BENCH_colocation.json").write_text(
        json.dumps(out, indent=1))
    return rows
