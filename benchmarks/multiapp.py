"""Paper Fig. 6 + 7: multi-application colocations. Sampled 2- and 3-way
mixes of the 10 archs per service; violin stats (min/mean/max) of normalized
tail latency, execution time, and inaccuracy; round-robin balance check."""
from __future__ import annotations

import itertools
import json

import numpy as np

from benchmarks.common import RESULTS_DIR, Rows, job_for
from repro.configs import ARCHS
from repro.core.colocation import SERVICES, simulate


def main(rows: Rows):
    archs = list(ARCHS)
    rng = np.random.default_rng(0)
    mixes2 = [tuple(rng.choice(archs, 2, replace=False)) for _ in range(6)]
    mixes3 = [tuple(rng.choice(archs, 3, replace=False)) for _ in range(6)]
    out = {}
    for svc_name, svc in SERVICES.items():
        for n_apps, mixes in [(1, [(a,) for a in archs[:6]]),
                              (2, mixes2), (3, mixes3)]:
            p99n, execn, inacc, spreads = [], [], [], []
            for mix in mixes:
                jobs = [job_for(a, total_work=500.0) for a in mix]
                res = simulate(svc, jobs, horizon_s=420,
                               seed=hash(mix) % 2**31)
                p99n += [p.p99 / svc.qos_target_s for p in res.timeline[5:]]
                execn += [res.exec_time(j) / jobs[j].total_work
                          for j in range(len(jobs))]
                losses = [j.quality_loss for j in jobs]
                inacc += losses
                if len(losses) > 1:
                    spreads.append(max(losses) - min(losses))
            key = f"{svc_name}|{n_apps}apps"
            out[key] = {
                "p99_norm": [float(np.min(p99n)), float(np.mean(p99n)),
                             float(np.max(p99n))],
                "exec_norm": [float(np.min(execn)), float(np.mean(execn)),
                              float(np.max(execn))],
                "inaccuracy": [float(np.min(inacc)), float(np.mean(inacc)),
                               float(np.max(inacc))],
                "loss_spread_max": float(max(spreads)) if spreads else 0.0,
            }
            rows.add(f"fig7.{svc_name}.{n_apps}apps",
                     out[key]["p99_norm"][1] * 100,
                     f"inacc_mean={out[key]['inaccuracy'][1]:.4f};"
                     f"spread={out[key]['loss_spread_max']:.4f}")
    (RESULTS_DIR / "multiapp_fig7.json").write_text(json.dumps(out, indent=1))
    return rows
