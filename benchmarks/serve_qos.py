"""Serving QoS benchmark: per serving-variant throughput and tail latency of
the continuous-batching engine on the reduced config, one Pliant-controlled
run, and a paged-engine run on a shared-prefix trace (page-pool occupancy,
prefix-cache hit rate, pool reclaim events) — the serve-side perf trajectory
(BENCH_serve.json)."""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

from benchmarks.common import RESULTS_DIR, Rows

ARCH = "gemma2-27b-smoke"
SLOTS, MAX_NEW, MAX_LEN, N_REQ, PROMPT = 4, 8, 32, 8, 6


def _drive(eng, cfg, rng, shared_prefix: int = 0, prompt_len: int = PROMPT,
           max_new: int = MAX_NEW):
    from repro.serve.engine import Request
    shared = list(rng.integers(1, cfg.vocab_size, shared_prefix))
    reqs = [Request(i, prompt=shared + list(
                        rng.integers(1, cfg.vocab_size,
                                     prompt_len - shared_prefix)),
                    max_new=max_new) for i in range(N_REQ)]
    import time
    t0 = time.perf_counter()
    for r in reqs:
        r.t_arrival = time.perf_counter()
        eng.submit(r)
    eng.run()
    wall = time.perf_counter() - t0
    toks = sum(len(r.out) for r in reqs)
    lat = np.asarray(eng.step_latencies, float)
    # queue WAIT (arrival -> first prefill chunk issued) vs admission
    # COMPUTE (pure prefill executable time): now that prefill interleaves
    # with decode in the paged engine, the old arrival->completion p95
    # conflated the two — report both
    qw = [r.t_admit_start - r.t_arrival for r in reqs
          if r.t_admit_start and r.t_arrival]
    ac = [r.admit_compute_s for r in reqs if r.t_admit]
    return {
        "tok_s": toks / max(wall, 1e-9),
        "wall_s": wall,
        "p50_ms": 1e3 * float(np.percentile(lat, 50)),
        "p95_ms": 1e3 * float(np.percentile(lat, 95)),
        "p99_ms": 1e3 * float(np.percentile(lat, 99)),
        "steps": len(lat),
        "queue_wait_p95_ms": 1e3 * float(np.percentile(qw, 95)) if qw else 0.0,
        "admit_compute_p95_ms": (1e3 * float(np.percentile(ac, 95))
                                 if ac else 0.0),
    }


def main(rows: Rows):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core.controller import ControllerConfig
    from repro.core.monitor import LatencyMonitor
    from repro.core.runtime import PliantRuntime
    from repro.launch.serve import serving_table
    from repro.models import api
    from repro.serve.engine import ServeEngine

    cfg = get_config(ARCH)
    params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    table = serving_table(cfg, slots=SLOTS, max_len=MAX_LEN)
    out = {}
    for vi, v in enumerate(table.variants):
        eng = ServeEngine(cfg, batch_slots=SLOTS, max_len=MAX_LEN,
                          params=params, table=table, sync_timing=True)
        eng.set_variant(vi)
        stats = _drive(eng, cfg, np.random.default_rng(0))
        out[v.name] = stats
        rows.add(f"serve.{v.name}", 1e3 * stats["p95_ms"],
                 f"tok_s={stats['tok_s']:.1f};p99_ms={stats['p99_ms']:.1f}")
    # QoS target between precise and most-approximate p95: violation rate per
    # variant against one shared target, plus a controlled (hot-swapping) run
    target_s = 0.5 * (out[table.variants[0].name]["p95_ms"]
                      + out[table.variants[-1].name]["p95_ms"]) / 1e3
    for vi, v in enumerate(table.variants):
        eng = ServeEngine(cfg, batch_slots=SLOTS, max_len=MAX_LEN,
                          params=params, table=table, sync_timing=True)
        eng.set_variant(vi)
        _drive(eng, cfg, np.random.default_rng(1))
        lat = np.asarray(eng.step_latencies, float)
        out[v.name]["qos_target_ms"] = 1e3 * target_s
        out[v.name]["violation_rate"] = float(np.mean(lat > target_s))
    monitor = LatencyMonitor(qos_target_s=target_s, window=1024)
    runtime = PliantRuntime(table, monitor,
                            ControllerConfig(decision_interval_s=0.05))
    eng = ServeEngine(cfg, batch_slots=SLOTS, max_len=MAX_LEN, params=params,
                      table=table, runtime=runtime, sync_timing=True)
    stats = _drive(eng, cfg, np.random.default_rng(2))
    stats["swaps"] = eng.swaps
    stats["final_variant"] = table.variants[eng.active_variant].name
    out["pliant"] = stats
    rows.add("serve.pliant", 1e3 * stats["p95_ms"],
             f"tok_s={stats['tok_s']:.1f};swaps={len(eng.swaps)}")

    # paged engine on a shared-prefix Poisson-style trace, Pliant-controlled
    # with an impossible target so the controller walks to most-approximate
    # and then reclaims pool pages: page-pool occupancy, prefix-cache hit
    # rate, and reclaim-event counts are the CI-tracked paged metrics
    monitor = LatencyMonitor(qos_target_s=1e-7, window=256,
                             min_samples=SLOTS)
    # the paged table prices decode HBM by live pages, kv_share anchored on
    # the compiled cell's cost_analysis (explorer.decode_kv_share)
    ptable = serving_table(cfg, slots=SLOTS, max_len=MAX_LEN,
                           page_occupancy=(PROMPT + MAX_NEW) / MAX_LEN,
                           price_from_compile=True)
    runtime = PliantRuntime(ptable, monitor,
                            ControllerConfig(decision_interval_s=0.0))
    eng = ServeEngine(cfg, batch_slots=SLOTS, max_len=MAX_LEN, params=params,
                      runtime=runtime, paged=True, page_size=4,
                      sync_timing=True)
    stats = _drive(eng, cfg, np.random.default_rng(3),
                   shared_prefix=PROMPT - 2)
    s = eng.pool.stats
    looks = s["prefix_hits"] + s["prefix_misses"]
    stats["pool_pages"] = eng.pool.spec.n_pages
    stats["pool_occupancy_peak"] = s["peak_used"] / eng.pool.spec.usable
    stats["prefix_hit_rate"] = s["prefix_hits"] / max(looks, 1)
    stats["tokens_skipped"] = s["tokens_skipped"]
    stats["reclaim_events"] = s["reclaim_events"]
    stats["swaps"] = eng.swaps
    out["paged"] = stats
    rows.add("serve.paged", 1e3 * stats["p95_ms"],
             f"tok_s={stats['tok_s']:.1f};"
             f"hit_rate={stats['prefix_hit_rate']:.2f};"
             f"reclaims={stats['reclaim_events']}")
    # dense vs paged vs megastep at EQUAL batch — the ROADMAP "close the
    # paged gap" acceptance metric, on the paged engine's target workload:
    # a shared system prompt (16-token prompts, 12 shared) with short
    # completions. Each engine runs the same trace twice: a warm-up pass
    # (compiles; paged prefix registration — the steady state a
    # long-running server sits in) and a measured pass with fresh
    # counters. All three run sync_timing (drain before stamping) so the
    # latency numbers measure compute, not async dispatch enqueue. CI
    # asserts paged tok/s >= dense, megastep tok/s >= paged, queue-wait
    # p95 within 1.25x, and megastep dispatches/token < 1.
    comparison = {}
    cmp_trace = dict(shared_prefix=12, prompt_len=16, max_new=6)
    for name, ekw in (("dense", dict(paged=False)),
                      ("paged", dict(paged=True)),
                      ("megastep", dict(paged=True, megastep_k=4))):
        eng = ServeEngine(cfg, batch_slots=SLOTS, max_len=MAX_LEN,
                          params=params, page_size=4, sync_timing=True,
                          **ekw)
        _drive(eng, cfg, np.random.default_rng(5), **cmp_trace)
        eng.step_latencies.clear()
        eng.admit_latencies.clear()
        eng.step_admission_chunks.clear()
        eng.decode_dispatches = eng.row_dispatches = eng.row_tokens = 0
        eng.drain_block_s = 0.0
        st = _drive(eng, cfg, np.random.default_rng(5), **cmp_trace)
        st["mesh_shape"] = dict(eng.mesh.shape) if eng.mesh is not None \
            else None
        st["sharded_kernel"] = eng.sharded_kernel
        st["decode_dispatches"] = eng.decode_dispatches
        st["dispatches_per_token"] = (eng.row_dispatches
                                      / max(eng.row_tokens, 1))
        # fraction of the wall the host spent NOT blocked on device
        # transfers — the megastep pipeline's target metric
        st["host_overhead_frac"] = max(
            0.0, 1.0 - eng.drain_block_s / max(st["wall_s"], 1e-9))
        if eng.paged:
            s = eng.pool.stats
            st["pool_occupancy_peak"] = s["peak_used"] / eng.pool.spec.usable
            st["grouped_pages"] = s["grouped_pages"]
            st["grouped_fallbacks"] = s["grouped_fallbacks"]
            st["admission_chunks_max"] = max(
                (c for c, _ in eng.step_admission_chunks), default=0)
        comparison[name] = st
    out["comparison"] = comparison
    ratio = comparison["paged"]["tok_s"] / max(comparison["dense"]["tok_s"],
                                               1e-9)
    rows.add("serve.paged_vs_dense", ratio,
             f"dense={comparison['dense']['tok_s']:.1f};"
             f"paged={comparison['paged']['tok_s']:.1f};"
             f"qw_dense_ms={comparison['dense']['queue_wait_p95_ms']:.1f};"
             f"qw_paged_ms={comparison['paged']['queue_wait_p95_ms']:.1f}")
    mega = comparison["megastep"]
    rows.add("serve.megastep_vs_paged",
             mega["tok_s"] / max(comparison["paged"]["tok_s"], 1e-9),
             f"tok_s={mega['tok_s']:.1f};"
             f"dispatches_per_token={mega['dispatches_per_token']:.2f};"
             f"host_overhead_frac={mega['host_overhead_frac']:.2f}")
    # admission compute per mesh shape: single-device whole-chunk cell vs
    # the ring-sequence-parallel cell on 8 simulated devices (subprocess —
    # device count is fixed at jax import). CI tracks admit_compute_p95
    # and the dispatch string per shape.
    admission = {"1x1": {
        "mesh_shape": None,
        "prefill_dispatch": eng.explain_prefill_dispatch(),
        "admit_compute_p95_ms": comparison["paged"]["admit_compute_p95_ms"],
    }}
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    proc = subprocess.run([sys.executable, "-c", _ADMIT_CHILD],
                          capture_output=True, text=True, env=env)
    line = next((l for l in proc.stdout.splitlines()
                 if l.startswith("ADMIT_JSON:")), None)
    assert line is not None, (proc.stdout, proc.stderr[-2000:])
    admission["2x4"] = json.loads(line[len("ADMIT_JSON:"):])
    out["admission"] = admission
    for shape, st in admission.items():
        rows.add(f"serve.admission.{shape}", st["admit_compute_p95_ms"],
                 st["prefill_dispatch"])
    # chaos smoke (8 simulated devices, subprocess): revoke 2 of 8 devices
    # mid-decode with a grace deadline, restore them later. The child runs
    # the SAME trace unfaulted first and asserts zero dropped requests and
    # exact greedy token parity — deflation must be invisible to clients —
    # then reports recovery time and QoS during the shrunk window. CI gates
    # on dropped == 0 and token_parity.
    proc = subprocess.run([sys.executable, "-c", _ELASTIC_CHILD],
                          capture_output=True, text=True, env=env)
    line = next((l for l in proc.stdout.splitlines()
                 if l.startswith("ELASTIC_JSON:")), None)
    assert line is not None, (proc.stdout, proc.stderr[-2000:])
    est = json.loads(line[len("ELASTIC_JSON:"):])
    out["elastic"] = est
    rows.add("serve.elastic", est["recovery_steps"],
             f"dropped={est['dropped']};parity={est['token_parity']};"
             f"pages={est['pages_migrated']};"
             f"qos_shrink_ms={est['qos_during_shrink_p95_ms']:.1f};"
             f"qos_steady_ms={est['qos_steady_p95_ms']:.1f}")
    (RESULTS_DIR / "BENCH_serve.json").write_text(json.dumps(out, indent=1))
    return rows


# one tiny sharded trace on 8 simulated host devices: the ring-prefill
# admission cell end to end through the paged engine (interpret-mode kernels)
_ADMIT_CHILD = """
import json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.serve.engine import Request, ServeEngine
cfg = get_config("gemma2-27b-smoke")
params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
mesh = make_mesh((2, 4), ("data", "model"))
eng = ServeEngine(cfg, batch_slots=4, max_len=32, params=params, mesh=mesh,
                  paged=True, page_size=4, prefill_chunk=8,
                  use_kernel=True, kernel_interpret=True)
rng = np.random.default_rng(0)
reqs = [Request(i, prompt=list(rng.integers(1, cfg.vocab_size, 6)),
                max_new=4) for i in range(4)]
for r in reqs:
    r.t_arrival = time.perf_counter()
    eng.submit(r)
eng.run()
ac = [r.admit_compute_s for r in reqs if r.t_admit]
out = {"mesh_shape": dict(eng.mesh.shape),
       "prefill_dispatch": eng.explain_prefill_dispatch(),
       "admit_compute_p95_ms": (1e3 * float(np.percentile(ac, 95))
                                if ac else 0.0)}
print("ADMIT_JSON:" + json.dumps(out))
"""

# the chaos smoke: 8 simulated devices, revoke 2 mid-decode (2-step grace),
# restore later; unfaulted reference run first, parity asserted IN the child
_ELASTIC_CHILD = """
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.dist import elastic
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.serve.engine import Request, ServeEngine

cfg = get_config("gemma2-27b-smoke")
params = api.init(cfg, jax.random.PRNGKey(0), jnp.float32)
rng = np.random.default_rng(7)
prompts = [list(rng.integers(1, cfg.vocab_size, 6)) for _ in range(8)]

def run(script):
    mesh = make_mesh((2, 4), ("data", "model"))
    eng = ServeEngine(cfg, batch_slots=4, max_len=32, params=params,
                      mesh=mesh, paged=True, page_size=4, prefill_chunk=8,
                      use_kernel=True, kernel_interpret=True)
    reqs = [Request(i, prompt=list(p), max_new=8)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    inj = elastic.FaultInjector.parse(script) if script else None
    steps = 0
    while not eng.idle and steps < 2000:
        if inj is not None:
            for ev in inj.due(steps):
                eng.inject(ev)
        eng.step()
        steps += 1
    assert eng.idle, "chaos run did not drain"
    return eng, reqs

ref_eng, ref = run("")
# grace deadline lands at step 4 — mid-decode of the first wave, so live
# pages migrate off the revoked shard; restore at 9 re-homes the second wave
eng, got = run("revoke@2+2:2,restore@9")
rehomes = [e for e in eng.elastic_log if "mesh_shape" in e]
assert len(rehomes) == 2, eng.elastic_log
shrink, grow = rehomes
lat = np.asarray(eng.step_latencies, float)
lo, hi = shrink["step_index"], grow["step_index"]
shrunk, steady = lat[lo:hi], np.concatenate([lat[:lo], lat[hi:]])
out = dict(
    dropped=sum(1 for r in got if not r.done) + len(eng.rejected),
    token_parity=bool([r.out for r in got] == [r.out for r in ref]),
    recovery_steps=shrink["recovery_steps"],
    grow_recovery_steps=grow["recovery_steps"],
    pages_migrated=shrink["pages_migrated"],
    cutover_s=shrink["cutover_s"],
    mesh_during_shrink=shrink["mesh_shape"],
    qos_during_shrink_p95_ms=(1e3 * float(np.percentile(shrunk, 95))
                              if len(shrunk) else 0.0),
    qos_steady_p95_ms=1e3 * float(np.percentile(steady, 95)))
assert out["dropped"] == 0 and out["token_parity"], out
print("ELASTIC_JSON:" + json.dumps(out))
"""
