"""Paper Fig. 9: decision-interval sensitivity (0.25s .. 8s) on the strict
service. Coarse intervals leave violations unresolved longer."""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import RESULTS_DIR, Rows, job_for
from repro.core.colocation import SERVICES, simulate

INTERVALS = [0.25, 0.5, 1.0, 2.0, 4.0, 8.0]


def main(rows: Rows):
    svc = SERVICES["token-serve"]
    out = {}
    for arch in ["phi4-mini-3.8b", "mamba2-780m", "olmoe-1b-7b"]:
        for iv in INTERVALS:
            job = job_for(arch, total_work=300.0)
            res = simulate(svc, [job], horizon_s=420, interval_s=iv, seed=41)
            out[f"{arch}|{iv}"] = {
                "met": res.qos_met_frac,
                "exec_ratio": res.exec_time() / job.total_work,
                "inaccuracy": job.quality_loss,
            }
        met = {iv: out[f"{arch}|{iv}"]["met"] for iv in INTERVALS}
        rows.add(f"fig9.{arch}", met[1.0] * 100,
                 f"met@0.5={met[0.5]:.2f};met@1={met[1.0]:.2f};"
                 f"met@8={met[8.0]:.2f};fine_beats_coarse="
                 f"{met[1.0] >= met[8.0]}")
    (RESULTS_DIR / "interval_fig9.json").write_text(json.dumps(out, indent=1))
    return rows
